//! Composable scenario library (DESIGN.md §14): production-shaped
//! request sources that go beyond the synthetic Poisson/Zipf
//! generator.
//!
//! Four generators, each a [`RequestSource`]:
//!
//! * **chat** — multi-turn conversations with shared-prefix
//!   accounting: turn N's prefill is the full shared history (all
//!   prior prompts + responses) plus the new prompt, so context grows
//!   monotonically across a session (the KV-cache-shaped load
//!   "How Hungry is AI?" identifies as the dominant chat pattern);
//! * **agentic** — tool-call loops: many short turns per session with
//!   tight inter-turn gaps, producing correlated arrival clusters
//!   instead of memoryless Poisson spacing;
//! * **rag** — retrieval-augmented queries: a short question plus
//!   `k` retrieved chunks makes a long prefill, followed by a short
//!   grounded answer;
//! * **tenants** — a heavy-tailed multi-tenant mix: 8 tenants with
//!   Zipf-ranked QPS weights and per-tenant length/P:D profiles,
//!   superposed into one Poisson stream.
//!
//! Any set of sources composes through [`MixSource`], a k-way merge
//! that re-ids the union densely; `workload::source_from_config` wires
//! weighted mixes from `--workload mix:chat=2,rag=1`.
//!
//! Everything is driven by the crate's deterministic [`Rng`]: equal
//! seeds give bit-identical streams (pinned by the conformance suite
//! in `tests/workload_sources.rs`), and each session forks its own
//! stream so adding a turn to one conversation never perturbs another.

use crate::util::rng::{Rng, Zipf};
use crate::workload::request::Request;
use crate::workload::store::RequestSource;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Shape of one session-based scenario (chat, agentic): how many
/// turns a session runs, how long prompts/responses are, and how the
/// next turn's arrival trails the previous turn's completion.
#[derive(Debug, Clone)]
pub struct SessionProfile {
    /// Mean turns per session (>= 1; actual turns are
    /// `1 + Poisson(mean_turns - 1)`).
    pub mean_turns: f64,
    /// Per-turn new-prompt length.
    pub prompt: Zipf,
    /// Per-turn response length.
    pub response: Zipf,
    /// Mean user think time between turns, seconds (exponential).
    pub think_mean_s: f64,
    /// Crude decode-latency model: the next turn can only start after
    /// the previous response streamed out at this many seconds per
    /// token.
    pub latency_s_per_token: f64,
}

impl SessionProfile {
    /// Interactive chat: a handful of turns, mid-sized prompts and
    /// responses, tens of seconds of think time.
    pub fn chat() -> SessionProfile {
        SessionProfile {
            mean_turns: 4.0,
            prompt: Zipf::new(32, 512, 0.8),
            response: Zipf::new(16, 384, 0.7),
            think_mean_s: 20.0,
            latency_s_per_token: 0.05,
        }
    }

    /// Agentic tool-call loop: many short turns back to back — the
    /// next call fires as soon as the previous result lands, so one
    /// session is a correlated burst of arrivals.
    pub fn agentic() -> SessionProfile {
        SessionProfile {
            mean_turns: 12.0,
            prompt: Zipf::new(16, 128, 0.9),
            response: Zipf::new(8, 96, 0.9),
            think_mean_s: 0.4,
            latency_s_per_token: 0.03,
        }
    }
}

/// One in-flight session: its private RNG stream, remaining turn
/// budget, and the shared-prefix token count carried between turns.
///
/// Exposed so tests can drive the shared-prefix accounting directly
/// (the history-monotonicity property in this module's tests).
#[derive(Debug, Clone)]
pub struct Conversation {
    rng: Rng,
    remaining_turns: u64,
    history_tokens: u64,
}

impl Conversation {
    /// Start a session; `rng` is the session's private fork.
    pub fn start(profile: &SessionProfile, mut rng: Rng) -> Conversation {
        let extra = if profile.mean_turns > 1.0 {
            rng.poisson(profile.mean_turns - 1.0)
        } else {
            0
        };
        Conversation {
            rng,
            remaining_turns: 1 + extra,
            history_tokens: 0,
        }
    }

    /// Produce the next turn's `(prefill, decode)` token budgets, or
    /// `None` once the session is over.
    ///
    /// Shared-prefix accounting: the prefill covers the whole shared
    /// history plus the new prompt; afterwards both the prompt and the
    /// generated response join the history, which therefore never
    /// shrinks. Both budgets are clamped so
    /// `prefill + decode <= max_tokens` (a long conversation
    /// saturates the context window rather than overflowing it).
    pub fn next_turn(&mut self, profile: &SessionProfile, max_tokens: u64) -> Option<(u64, u64)> {
        if self.remaining_turns == 0 {
            return None;
        }
        self.remaining_turns -= 1;
        let prompt = profile.prompt.sample(&mut self.rng);
        let response = profile.response.sample(&mut self.rng);
        let decode = response.clamp(1, max_tokens.saturating_sub(1).max(1));
        let prefill = (self.history_tokens + prompt).clamp(1, (max_tokens - decode).max(1));
        self.history_tokens += prompt + response;
        Some((prefill, decode))
    }

    /// Shared-history size in tokens (monotone nondecreasing).
    pub fn history_tokens(&self) -> u64 {
        self.history_tokens
    }

    /// Turns left before the session ends.
    pub fn remaining_turns(&self) -> u64 {
        self.remaining_turns
    }

    /// Seconds until this session's next turn arrives, measured from
    /// the completion of a `decode`-token response.
    fn next_gap_s(&mut self, profile: &SessionProfile, decode: u64) -> f64 {
        decode as f64 * profile.latency_s_per_token
            + self.rng.exponential(1.0 / profile.think_mean_s)
    }
}

/// A scheduled future turn in [`SessionSource`]'s event queue.
#[derive(Debug, Clone, Copy)]
struct Pending {
    at: f64,
    /// Tie-break so equal times pop in schedule order (determinism).
    seq: u64,
    slot: usize,
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Pending {}
impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Pending {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at.total_cmp(&other.at).then(self.seq.cmp(&other.seq))
    }
}

/// Session-based scenario source (chat, agentic): new sessions open
/// as a Poisson process; each session then emits its turns on its own
/// think-time clock. The source merges all pending turns and future
/// session starts into one nondecreasing arrival stream.
///
/// The stream is infinite (sessions keep opening); callers cap it —
/// `workload::source_from_config` wraps it to `cfg.num_requests`.
pub struct SessionSource {
    profile: SessionProfile,
    /// New-session rate, chosen so the long-run *request* rate is the
    /// configured QPS: sessions/s = qps / mean_turns.
    session_rate: f64,
    max_tokens: u64,
    rng: Rng,
    heap: BinaryHeap<Reverse<Pending>>,
    sessions: Vec<Option<Conversation>>,
    free_slots: Vec<usize>,
    next_session_s: f64,
    next_seq: u64,
    sessions_started: u64,
    next_id: u64,
}

impl SessionSource {
    pub fn new(profile: SessionProfile, qps: f64, max_tokens: u64, seed: u64) -> SessionSource {
        assert!(qps.is_finite() && qps > 0.0, "session source needs a positive rate");
        assert!(profile.mean_turns >= 1.0, "mean_turns must be >= 1");
        let mut rng = Rng::new(seed ^ 0x5E55_1014);
        let session_rate = qps / profile.mean_turns;
        let first = rng.exponential(session_rate);
        SessionSource {
            profile,
            session_rate,
            max_tokens,
            rng,
            heap: BinaryHeap::new(),
            sessions: Vec::new(),
            free_slots: Vec::new(),
            next_session_s: first,
            next_seq: 0,
            sessions_started: 0,
            next_id: 0,
        }
    }

    /// Convenience constructors for the built-in scenario kinds.
    pub fn chat(qps: f64, max_tokens: u64, seed: u64) -> SessionSource {
        SessionSource::new(SessionProfile::chat(), qps, max_tokens, seed)
    }
    pub fn agentic(qps: f64, max_tokens: u64, seed: u64) -> SessionSource {
        SessionSource::new(SessionProfile::agentic(), qps, max_tokens, seed)
    }

    fn schedule(&mut self, at: f64, slot: usize) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Pending { at, seq, slot }));
    }

    /// Open the session arriving at `next_session_s` and schedule its
    /// first turn there.
    fn open_session(&mut self) {
        let at = self.next_session_s;
        self.sessions_started += 1;
        // Private stream per session: turn lengths and think times of
        // one conversation never depend on how many others are open.
        let fork = self.rng.fork(self.sessions_started);
        let convo = Conversation::start(&self.profile, fork);
        let slot = match self.free_slots.pop() {
            Some(s) => {
                self.sessions[s] = Some(convo);
                s
            }
            None => {
                self.sessions.push(Some(convo));
                self.sessions.len() - 1
            }
        };
        self.schedule(at, slot);
        self.next_session_s = at + self.rng.exponential(self.session_rate);
    }
}

impl RequestSource for SessionSource {
    fn next_request(&mut self) -> Option<Request> {
        loop {
            // Admit every session that opens before the earliest
            // pending turn, so emissions stay globally nondecreasing.
            while self
                .heap
                .peek()
                .is_none_or(|Reverse(p)| self.next_session_s <= p.at)
            {
                self.open_session();
            }
            let Reverse(p) = self.heap.pop().expect("session heap cannot be empty here");
            let convo = self.sessions[p.slot]
                .as_mut()
                .expect("pending turn for a closed session");
            match convo.next_turn(&self.profile, self.max_tokens) {
                Some((prefill, decode)) => {
                    if convo.remaining_turns() > 0 {
                        let gap = convo.next_gap_s(&self.profile, decode);
                        self.schedule(p.at + gap, p.slot);
                    } else {
                        self.sessions[p.slot] = None;
                        self.free_slots.push(p.slot);
                    }
                    let id = self.next_id;
                    self.next_id += 1;
                    return Some(Request::new(id, p.at, prefill, decode));
                }
                None => {
                    // Zero-turn sessions cannot happen (min 1 turn),
                    // but stay robust: close the slot and move on.
                    self.sessions[p.slot] = None;
                    self.free_slots.push(p.slot);
                }
            }
        }
    }
}

/// RAG-style source: stateless Poisson arrivals where each request's
/// prefill is a short query plus `k` retrieved chunks (long prefill)
/// and the decode is a short grounded answer.
pub struct RagSource {
    rng: Rng,
    qps: f64,
    clock_s: f64,
    query: Zipf,
    answer: Zipf,
    /// Retrieved chunks per query, uniform in `2..=8`.
    chunk_tokens: u64,
    max_tokens: u64,
    next_id: u64,
}

impl RagSource {
    pub fn new(qps: f64, max_tokens: u64, seed: u64) -> RagSource {
        assert!(qps.is_finite() && qps > 0.0, "rag source needs a positive rate");
        RagSource {
            rng: Rng::new(seed ^ 0x4A6_0BA6),
            qps,
            clock_s: 0.0,
            query: Zipf::new(16, 128, 0.8),
            answer: Zipf::new(32, 256, 0.8),
            chunk_tokens: 256,
            max_tokens,
            next_id: 0,
        }
    }
}

impl RequestSource for RagSource {
    fn next_request(&mut self) -> Option<Request> {
        self.clock_s += self.rng.exponential(self.qps);
        let k = self.rng.int_range(2, 8);
        let decode = self
            .answer
            .sample(&mut self.rng)
            .clamp(1, self.max_tokens.saturating_sub(1).max(1));
        let prefill = (self.query.sample(&mut self.rng) + k * self.chunk_tokens)
            .clamp(1, (self.max_tokens - decode).max(1));
        let id = self.next_id;
        self.next_id += 1;
        Some(Request::new(id, self.clock_s, prefill, decode))
    }
}

/// One tenant in the multi-tenant mix.
#[derive(Debug, Clone)]
struct Tenant {
    lengths: Zipf,
    pd_ratio: f64,
}

/// Heavy-tailed multi-tenant mix: `n` tenants whose traffic shares
/// follow a Zipf rank-weight law (`weight ∝ 1/(rank+1)^1.2`), each
/// with its own length distribution and P:D ratio. The superposition
/// of the per-tenant Poisson streams is itself Poisson at the total
/// QPS, so arrivals are drawn from one aggregate clock and each
/// request picks its tenant by weight.
pub struct TenantMixSource {
    rng: Rng,
    qps: f64,
    clock_s: f64,
    tenants: Vec<Tenant>,
    /// Normalized traffic shares, one per tenant.
    weights: Vec<f64>,
    /// Requests emitted per tenant (for the convergence property).
    counts: Vec<u64>,
    max_tokens: u64,
    next_id: u64,
}

impl TenantMixSource {
    pub const NUM_TENANTS: usize = 8;

    pub fn new(qps: f64, max_tokens: u64, seed: u64) -> TenantMixSource {
        assert!(qps.is_finite() && qps > 0.0, "tenant mix needs a positive rate");
        let mut rng = Rng::new(seed ^ 0x7E4A_4713);
        let n = Self::NUM_TENANTS;
        let raw: Vec<f64> = (0..n).map(|r| 1.0 / ((r + 1) as f64).powf(1.2)).collect();
        let total: f64 = raw.iter().sum();
        let weights: Vec<f64> = raw.iter().map(|w| w / total).collect();
        // Per-tenant length/shape profiles: big tenants skew long and
        // prefill-heavy (workhorse apps), tail tenants run short
        // interactive traffic.
        let tenants: Vec<Tenant> = (0..n)
            .map(|r| {
                let hi = (1024 >> (r / 3)).max(128) as u64;
                let lo = (hi / 16).max(8);
                Tenant {
                    lengths: Zipf::new(lo, hi, 0.6 + 0.05 * r as f64),
                    pd_ratio: rng.uniform(0.5, 8.0),
                }
            })
            .collect();
        TenantMixSource {
            rng,
            qps,
            clock_s: 0.0,
            tenants,
            weights,
            counts: vec![0; n],
            max_tokens,
            next_id: 0,
        }
    }

    /// Normalized per-tenant traffic shares.
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    /// Requests emitted so far, per tenant.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }
}

impl RequestSource for TenantMixSource {
    fn next_request(&mut self) -> Option<Request> {
        self.clock_s += self.rng.exponential(self.qps);
        // Weight-proportional tenant pick off the aggregate stream.
        let u = self.rng.f64();
        let mut acc = 0.0;
        let mut pick = self.tenants.len() - 1;
        for (i, w) in self.weights.iter().enumerate() {
            acc += w;
            if u < acc {
                pick = i;
                break;
            }
        }
        self.counts[pick] += 1;
        let t = &self.tenants[pick];
        let total = t.lengths.sample(&mut self.rng).clamp(2, self.max_tokens);
        let (prefill, decode) = Request::split_by_ratio(total, t.pd_ratio);
        let id = self.next_id;
        self.next_id += 1;
        Some(Request::new(id, self.clock_s, prefill, decode))
    }
}

/// K-way merge of child sources into one stream: always emits the
/// earliest pending child arrival (ties broken by child index) and
/// re-ids the union densely so the engine's ids-are-`0..n` contract
/// holds. Children must themselves be nondecreasing.
pub struct MixSource {
    children: Vec<Box<dyn RequestSource>>,
    pending: Vec<Option<Request>>,
    primed: bool,
    next_id: u64,
}

impl MixSource {
    pub fn new(children: Vec<Box<dyn RequestSource>>) -> MixSource {
        assert!(!children.is_empty(), "mix needs at least one child source");
        let n = children.len();
        MixSource {
            children,
            pending: (0..n).map(|_| None).collect(),
            primed: false,
            next_id: 0,
        }
    }
}

impl RequestSource for MixSource {
    fn next_request(&mut self) -> Option<Request> {
        if !self.primed {
            for (i, c) in self.children.iter_mut().enumerate() {
                self.pending[i] = c.next_request();
            }
            self.primed = true;
        }
        let best = self
            .pending
            .iter()
            .enumerate()
            .filter_map(|(i, p)| p.as_ref().map(|r| (i, r.arrival_s)))
            .min_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)))
            .map(|(i, _)| i)?;
        let mut req = self.pending[best].take().expect("winning slot must be pending");
        self.pending[best] = self.children[best].next_request();
        req.id = self.next_id;
        self.next_id += 1;
        Some(req)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gens};

    fn drain(src: &mut dyn RequestSource, n: usize) -> Vec<Request> {
        (0..n).map(|_| src.next_request().expect("infinite source")).collect()
    }

    #[test]
    fn chat_arrivals_monotone_ids_dense() {
        let mut src = SessionSource::chat(8.0, 2048, 7);
        let reqs = drain(&mut src, 500);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.prefill_tokens >= 1 && r.decode_tokens >= 1);
            assert!(r.prefill_tokens + r.decode_tokens <= 2048, "{r:?}");
        }
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
    }

    #[test]
    fn agentic_turns_cluster_tighter_than_chat() {
        // Same request rate; agentic sessions should pack far more of
        // their inter-arrival gaps under a second than chat does.
        let frac_small = |profile: fn(f64, u64, u64) -> SessionSource| {
            let reqs = drain(&mut profile(5.0, 4096, 11), 800);
            let small = reqs
                .windows(2)
                .filter(|w| w[1].arrival_s - w[0].arrival_s < 1.0)
                .count();
            small as f64 / (reqs.len() - 1) as f64
        };
        let agentic = frac_small(SessionSource::agentic);
        let chat = frac_small(SessionSource::chat);
        assert!(
            agentic > chat + 0.1,
            "agentic bursts not tighter: agentic {agentic:.2} vs chat {chat:.2}"
        );
    }

    #[test]
    fn rag_is_prefill_heavy() {
        let mut src = RagSource::new(10.0, 4096, 3);
        let reqs = drain(&mut src, 400);
        let p: u64 = reqs.iter().map(|r| r.prefill_tokens).sum();
        let d: u64 = reqs.iter().map(|r| r.decode_tokens).sum();
        assert!(p > 4 * d, "rag must be prefill-dominant: prefill {p}, decode {d}");
        // Chunked retrieval: prefill at least query_min + 2 chunks.
        assert!(reqs.iter().all(|r| r.prefill_tokens >= 16 + 2 * 256));
    }

    #[test]
    fn equal_seeds_equal_streams() {
        let builders: [fn(u64) -> Box<dyn RequestSource>; 4] = [
            |s| Box::new(SessionSource::chat(6.0, 2048, s)),
            |s| Box::new(SessionSource::agentic(6.0, 2048, s)),
            |s| Box::new(RagSource::new(6.0, 2048, s)),
            |s| Box::new(TenantMixSource::new(6.0, 2048, s)),
        ];
        for build in builders {
            let a = drain(&mut *build(42), 200);
            let b = drain(&mut *build(42), 200);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert!(x.arrival_s == y.arrival_s, "{x:?} vs {y:?}");
                assert_eq!(x.prefill_tokens, y.prefill_tokens);
                assert_eq!(x.decode_tokens, y.decode_tokens);
            }
        }
    }

    #[test]
    fn mix_merges_by_arrival_and_reids() {
        let children: Vec<Box<dyn RequestSource>> = vec![
            Box::new(RagSource::new(4.0, 2048, 1)),
            Box::new(TenantMixSource::new(4.0, 2048, 2)),
        ];
        let mut mix = MixSource::new(children);
        let reqs = drain(&mut mix, 300);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
    }

    // --- property tests (satellite: proptest harness) ---

    #[test]
    fn prop_shared_prefix_history_never_shrinks() {
        check(60, gens::u64_in(0, 1 << 48), |&seed| {
            let profile = SessionProfile::chat();
            let mut convo = Conversation::start(&profile, Rng::new(seed));
            let mut last_history = 0u64;
            let mut last_prefill = 0u64;
            while let Some((prefill, decode)) = convo.next_turn(&profile, 4096) {
                if convo.history_tokens() < last_history {
                    return Err(format!(
                        "history shrank: {} -> {}",
                        last_history,
                        convo.history_tokens()
                    ));
                }
                if prefill + decode > 4096 {
                    return Err(format!("context overflow: {prefill}+{decode}"));
                }
                // Prefill tracks the growing history until the window
                // clamp kicks in.
                if prefill < last_prefill && prefill + decode < 4096 {
                    return Err(format!(
                        "unclamped prefill shrank: {last_prefill} -> {prefill}"
                    ));
                }
                last_history = convo.history_tokens();
                last_prefill = prefill;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_tenant_shares_converge_to_weights() {
        check(10, gens::u64_in(0, 1 << 48), |&seed| {
            let mut src = TenantMixSource::new(10.0, 2048, seed);
            let n = 20_000usize;
            for _ in 0..n {
                src.next_request();
            }
            let weights = src.weights().to_vec();
            for (i, (&c, &w)) in src.counts().iter().zip(&weights).enumerate() {
                let share = c as f64 / n as f64;
                if (share - w).abs() > 0.02 {
                    return Err(format!(
                        "tenant {i}: share {share:.4} vs weight {w:.4} (n={n})"
                    ));
                }
            }
            Ok(())
        });
    }
}
