//! Workload layer: requests, arrival processes, length distributions,
//! trace export/replay, and the pull-based request plumbing the engine
//! streams from — the Vidur-side request generators.

pub mod request;
pub mod generator;
pub mod split;
pub mod store;
pub mod trace;

pub use generator::{LazyWorkload, WorkloadGenerator};
pub use request::{Request, RequestId};
pub use split::{split_round_robin, split_trace, SplitSource};
pub use store::{LiveRequests, RequestSource, RequestStore};
pub use trace::{Trace, TraceSource};
