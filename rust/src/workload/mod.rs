//! Workload layer: requests, arrival processes, length distributions,
//! trace export/replay, scenario generators, and the pull-based
//! request plumbing the engine streams from — the Vidur-side request
//! generators.
//!
//! [`source_from_config`] is the single entry point that turns a
//! [`SimConfig`]'s [`WorkloadKind`] into a running [`RequestSource`]:
//! the synthetic generator, a streamed trace replay, a scenario
//! generator, or a weighted mix (DESIGN.md §14).

pub mod request;
pub mod generator;
pub mod replay;
pub mod scenario;
pub mod split;
pub mod store;
pub mod trace;

pub use generator::{LazyWorkload, WorkloadGenerator};
pub use replay::ReplaySource;
pub use request::{Request, RequestId};
pub use scenario::{MixSource, RagSource, SessionProfile, SessionSource, TenantMixSource};
pub use split::{split_round_robin, split_trace, SplitSource};
pub use store::{LiveRequests, RequestSource, RequestStore};
pub use trace::{Trace, TraceSource};

use crate::config::simconfig::{Arrival, SimConfig, WorkloadKind};
use crate::util::rng::case_seed;
use anyhow::{bail, Result};
use std::sync::Mutex;

/// Process-wide workload override (the `--workload` flag on sweep
/// commands): when set, every [`source_from_config`] call uses this
/// kind instead of the per-case `cfg.workload` — the workload analogue
/// of the `--oracle` cost-model override.
static WORKLOAD_OVERRIDE: Mutex<Option<WorkloadKind>> = Mutex::new(None);

/// Set or clear the process-wide workload override.
pub fn set_workload_override(kind: Option<WorkloadKind>) {
    *WORKLOAD_OVERRIDE.lock().unwrap() = kind;
}

/// The active process-wide workload override, if any.
pub fn workload_override() -> Option<WorkloadKind> {
    WORKLOAD_OVERRIDE.lock().unwrap().clone()
}

/// The workload a run of `cfg` actually uses: the process override
/// when set, else `cfg.workload`.
pub fn effective_workload(cfg: &SimConfig) -> WorkloadKind {
    workload_override().unwrap_or_else(|| cfg.workload.clone())
}

/// Caps an (often infinite) source at `n` requests — scenario
/// generators never exhaust on their own, so `cfg.num_requests` bounds
/// the run the same way it bounds the synthetic generator.
pub struct Capped {
    inner: Box<dyn RequestSource>,
    remaining: u64,
}

impl Capped {
    pub fn new(inner: Box<dyn RequestSource>, n: u64) -> Capped {
        Capped { inner, remaining: n }
    }
}

impl RequestSource for Capped {
    fn next_request(&mut self) -> Option<Request> {
        if self.remaining == 0 {
            return None;
        }
        let r = self.inner.next_request()?;
        self.remaining -= 1;
        Some(r)
    }
}

/// The aggregate request rate scenario generators run at; scenarios
/// are open-loop arrival processes, so a batch (everything at t=0)
/// arrival has no rate to give them.
fn scenario_qps(cfg: &SimConfig, kind: &WorkloadKind) -> Result<f64> {
    let qps = cfg.arrival.qps();
    if !qps.is_finite() || qps <= 0.0 {
        bail!(
            "workload '{}' needs a rate-based arrival process for its request rate \
             (batch arrivals have none) — set a Poisson/Gamma qps",
            kind.spec()
        );
    }
    Ok(qps)
}

/// Build one mixable scenario component at an explicit rate. `stream`
/// decorrelates sibling components of a mix.
fn component_source(
    name: &str,
    cfg: &SimConfig,
    qps: f64,
    stream: u64,
) -> Result<Box<dyn RequestSource>> {
    let seed = case_seed(cfg.seed, stream);
    Ok(match name {
        "synthetic" => Box::new(
            WorkloadGenerator::new(
                Arrival::Poisson { qps },
                cfg.lengths.clone(),
                cfg.prefill_decode_ratio,
                cfg.max_tokens,
                seed,
            )
            .take(u64::MAX),
        ),
        "chat" => Box::new(SessionSource::chat(qps, cfg.max_tokens, seed)),
        "rag" => Box::new(RagSource::new(qps, cfg.max_tokens, seed)),
        "agentic" => Box::new(SessionSource::agentic(qps, cfg.max_tokens, seed)),
        "tenants" => Box::new(TenantMixSource::new(qps, cfg.max_tokens, seed)),
        k => bail!("unknown scenario component '{k}'"),
    })
}

/// Turn `cfg` into a running [`RequestSource`] per its effective
/// [`WorkloadKind`] (process override first, then `cfg.workload`).
///
/// Every non-synthetic stream is capped at `cfg.num_requests`; a
/// replayed trace ends at whichever comes first, its last row (times
/// `repeat`) or the cap. The synthetic path is byte-identical to the
/// pre-§14 `WorkloadGenerator::from_config(cfg).take(n)` pipeline.
pub fn source_from_config(cfg: &SimConfig) -> Result<Box<dyn RequestSource>> {
    let kind = effective_workload(cfg);
    kind.validate()?;
    let inner: Box<dyn RequestSource> = match &kind {
        WorkloadKind::Synthetic => {
            return Ok(Box::new(WorkloadGenerator::from_config(cfg).take(cfg.num_requests)));
        }
        WorkloadKind::Trace { path, time_scale, repeat } => {
            Box::new(ReplaySource::open(path, *time_scale, *repeat)?)
        }
        WorkloadKind::Chat => Box::new(SessionSource::chat(
            scenario_qps(cfg, &kind)?,
            cfg.max_tokens,
            cfg.seed,
        )),
        WorkloadKind::Rag => Box::new(RagSource::new(
            scenario_qps(cfg, &kind)?,
            cfg.max_tokens,
            cfg.seed,
        )),
        WorkloadKind::Agentic => Box::new(SessionSource::agentic(
            scenario_qps(cfg, &kind)?,
            cfg.max_tokens,
            cfg.seed,
        )),
        WorkloadKind::Tenants => Box::new(TenantMixSource::new(
            scenario_qps(cfg, &kind)?,
            cfg.max_tokens,
            cfg.seed,
        )),
        WorkloadKind::Mix(parts) => {
            let qps = scenario_qps(cfg, &kind)?;
            let total: f64 = parts.iter().map(|(_, w)| w).sum();
            let mut children = Vec::with_capacity(parts.len());
            for (i, (name, w)) in parts.iter().enumerate() {
                children.push(component_source(name, cfg, qps * w / total, i as u64)?);
            }
            Box::new(MixSource::new(children))
        }
    };
    Ok(Box::new(Capped::new(inner, cfg.num_requests)))
}

/// Materialize `cfg`'s workload as a [`Trace`] (for engine entry
/// points that need the whole workload up front, e.g. the autoscaler's
/// horizon scan). For trace replay this propagates malformed-row
/// errors instead of truncating at them.
pub fn trace_from_config(cfg: &SimConfig) -> Result<Trace> {
    if let WorkloadKind::Trace { path, time_scale, repeat } = &effective_workload(cfg) {
        let mut src = ReplaySource::open(path, *time_scale, *repeat)?;
        let mut requests = Vec::new();
        while (requests.len() as u64) < cfg.num_requests {
            match src.try_next()? {
                Some(r) => requests.push(r),
                None => break,
            }
        }
        return Ok(Trace::new(requests));
    }
    let mut src = source_from_config(cfg)?;
    let mut requests = Vec::new();
    while let Some(r) = src.next_request() {
        requests.push(r);
    }
    Ok(Trace::new(requests))
}
