//! Workload layer: requests, arrival processes, length distributions,
//! trace export/replay — the Vidur-side request generators.

pub mod request;
pub mod generator;
pub mod trace;

pub use generator::WorkloadGenerator;
pub use request::{Request, RequestId};
pub use trace::Trace;
