//! Request generation: arrival processes (Poisson / Gamma / batch) ×
//! length distributions (Zipf / fixed / uniform) with optional
//! prefill:decode ratio control — the knobs the paper's experiments
//! sweep (Table 1a; Exp. 2 P:D ratios; Exp. 4 QPS).

use crate::config::simconfig::{Arrival, LengthDist, SimConfig};
use crate::util::rng::{Rng, Zipf};
use crate::workload::request::Request;
use crate::workload::store::RequestSource;

/// Default prefill fraction when no P:D ratio is given: LLM chat
/// workloads are prompt-heavy; Vidur's default traces use roughly
/// 4:1 prompt:output.
const DEFAULT_PD_RATIO: f64 = 4.0;

/// Deterministic request-stream generator.
pub struct WorkloadGenerator {
    rng: Rng,
    arrival: Arrival,
    lengths: LengthDist,
    pd_ratio: f64,
    max_tokens: u64,
    zipf: Option<Zipf>,
    next_id: u64,
    clock_s: f64,
}

impl WorkloadGenerator {
    pub fn from_config(cfg: &SimConfig) -> Self {
        Self::new(
            cfg.arrival.clone(),
            cfg.lengths.clone(),
            cfg.prefill_decode_ratio,
            cfg.max_tokens,
            cfg.seed,
        )
    }

    pub fn new(
        arrival: Arrival,
        lengths: LengthDist,
        pd_ratio: Option<f64>,
        max_tokens: u64,
        seed: u64,
    ) -> Self {
        let zipf = match &lengths {
            LengthDist::Zipf { theta, min, max } => Some(Zipf::new(*min, *max, *theta)),
            _ => None,
        };
        WorkloadGenerator {
            rng: Rng::new(seed),
            arrival,
            lengths,
            pd_ratio: pd_ratio.unwrap_or(DEFAULT_PD_RATIO),
            max_tokens,
            zipf,
            next_id: 0,
            clock_s: 0.0,
        }
    }

    fn sample_total(&mut self) -> u64 {
        let total = match &self.lengths {
            LengthDist::Zipf { .. } => self.zipf.as_ref().unwrap().sample(&mut self.rng),
            LengthDist::Fixed { total } => *total,
            LengthDist::Uniform { min, max } => self.rng.int_range(*min, *max),
        };
        total.clamp(2, self.max_tokens)
    }

    fn advance_clock(&mut self) -> f64 {
        match &self.arrival {
            Arrival::Poisson { qps } => {
                self.clock_s += self.rng.exponential(*qps);
            }
            Arrival::Gamma { qps, cv } => {
                // Gamma inter-arrivals with mean 1/qps and the given
                // coefficient of variation: shape k = 1/cv², scale θ = cv²/qps.
                let k = 1.0 / (cv * cv);
                let theta = cv * cv / qps;
                self.clock_s += self.rng.gamma(k, theta);
            }
            Arrival::Batch => {}
        }
        self.clock_s
    }

    /// Generate the next request.
    pub fn next_request(&mut self) -> Request {
        let at = self.advance_clock();
        let total = self.sample_total();
        let (prefill, decode) = Request::split_by_ratio(total, self.pd_ratio);
        let id = self.next_id;
        self.next_id += 1;
        Request::new(id, at, prefill, decode)
    }

    /// Generate a full workload of `n` requests (sorted by arrival).
    pub fn generate(&mut self, n: u64) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }

    /// Turn the generator into a pull-based [`RequestSource`] capped at
    /// `n` requests: the engine draws arrivals one at a time, so the
    /// workload is never materialized — the lazy front of the
    /// streaming-telemetry pipeline (DESIGN.md §8). Yields exactly the
    /// same request stream as [`Self::generate`] on the same seed
    /// (arrival clocks are monotone, ids sequential).
    pub fn take(self, n: u64) -> LazyWorkload {
        LazyWorkload {
            gen: self,
            remaining: n,
        }
    }
}

/// A capped, pull-based view over a [`WorkloadGenerator`]: O(1) memory
/// regardless of request count.
pub struct LazyWorkload {
    gen: WorkloadGenerator,
    remaining: u64,
}

impl RequestSource for LazyWorkload {
    fn next_request(&mut self) -> Option<Request> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        Some(self.gen.next_request())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gens};

    fn gen(qps: f64, seed: u64) -> WorkloadGenerator {
        WorkloadGenerator::new(
            Arrival::Poisson { qps },
            LengthDist::Zipf {
                theta: 0.6,
                min: 1024,
                max: 4096,
            },
            Some(20.0),
            4096,
            seed,
        )
    }

    #[test]
    fn deterministic_per_seed() {
        let a = gen(6.45, 7).generate(100);
        let b = gen(6.45, 7).generate(100);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.prefill_tokens, y.prefill_tokens);
        }
    }

    #[test]
    fn arrivals_monotone_and_rate_close() {
        let reqs = gen(20.0, 11).generate(20_000);
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        let span = reqs.last().unwrap().arrival_s;
        let rate = reqs.len() as f64 / span;
        assert!((rate - 20.0).abs() < 1.0, "rate {rate}");
    }

    #[test]
    fn lengths_within_bounds_and_ratio_respected() {
        let reqs = gen(6.45, 13).generate(5_000);
        for r in &reqs {
            let total = r.total_tokens();
            assert!((1024..=4096).contains(&total), "total {total}");
            assert!(r.prefill_tokens >= 1 && r.decode_tokens >= 1);
        }
        // Aggregate P:D close to 20.
        let p: u64 = reqs.iter().map(|r| r.prefill_tokens).sum();
        let d: u64 = reqs.iter().map(|r| r.decode_tokens).sum();
        let ratio = p as f64 / d as f64;
        assert!((ratio - 20.0).abs() < 2.0, "ratio {ratio}");
    }

    #[test]
    fn batch_arrival_all_at_zero() {
        let mut g = WorkloadGenerator::new(
            Arrival::Batch,
            LengthDist::Fixed { total: 256 },
            None,
            4096,
            1,
        );
        for r in g.generate(10) {
            assert_eq!(r.arrival_s, 0.0);
            assert_eq!(r.total_tokens(), 256);
        }
    }

    #[test]
    fn gamma_burstier_than_poisson() {
        // Compare coefficient of variation of inter-arrival times.
        let cv = |reqs: &[Request]| {
            let gaps: Vec<f64> = reqs.windows(2).map(|w| w[1].arrival_s - w[0].arrival_s).collect();
            let m = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let v = gaps.iter().map(|g| (g - m).powi(2)).sum::<f64>() / gaps.len() as f64;
            v.sqrt() / m
        };
        let pois = gen(5.0, 17).generate(20_000);
        let mut g = WorkloadGenerator::new(
            Arrival::Gamma { qps: 5.0, cv: 3.0 },
            LengthDist::Fixed { total: 100 },
            None,
            4096,
            17,
        );
        let gam = g.generate(20_000);
        assert!(cv(&gam) > 2.0 * cv(&pois), "gamma {} pois {}", cv(&gam), cv(&pois));
    }

    #[test]
    fn total_clamped_to_max_tokens() {
        let mut g = WorkloadGenerator::new(
            Arrival::Batch,
            LengthDist::Uniform { min: 100, max: 100_000 },
            None,
            4096,
            3,
        );
        for r in g.generate(500) {
            assert!(r.total_tokens() <= 4096);
        }
    }

    #[test]
    fn lazy_take_matches_generate() {
        let materialized = gen(6.45, 99).generate(200);
        let mut lazy = gen(6.45, 99).take(200);
        let mut n = 0;
        while let Some(r) = lazy.next_request() {
            let m = &materialized[n];
            assert_eq!(r.id, m.id);
            assert_eq!(r.arrival_s, m.arrival_s);
            assert_eq!(r.prefill_tokens, m.prefill_tokens);
            assert_eq!(r.decode_tokens, m.decode_tokens);
            n += 1;
        }
        assert_eq!(n, 200);
        assert!(lazy.next_request().is_none(), "source must stay exhausted");
    }

    #[test]
    fn property_any_seed_valid_requests() {
        check(30, gens::u64_in(0, u64::MAX / 2), |&seed| {
            let reqs = gen(6.45, seed).generate(50);
            for r in &reqs {
                if r.prefill_tokens == 0 || r.decode_tokens == 0 {
                    return Err(format!("empty phase in {r:?}"));
                }
                if r.total_tokens() > 4096 {
                    return Err(format!("too long: {r:?}"));
                }
            }
            Ok(())
        });
    }
}
