//! Streaming trace replay (DESIGN.md §14): feed the engine a recorded
//! arrival trace straight off disk, one row at a time, without ever
//! materializing it — O(1) resident rows no matter how long the trace.
//!
//! Two on-disk layouts are sniffed from the first line:
//!
//! * the native `trace.csv` schema written by [`crate::workload::Trace::save`]
//!   (`id,arrival_s,prefill_tokens,decode_tokens`), replayed verbatim —
//!   arrivals are **not** rebased, so replaying a saved trace
//!   reproduces the generator's stream bit-for-bit
//!   (`tests/workload_replay.rs` proves the stage/request CSVs
//!   byte-identical);
//! * an Azure-LLM-inference-style layout
//!   (`timestamp,prompt_tokens,output_tokens`, CSV or JSONL), rebased
//!   so the first row arrives at t=0.
//!
//! JSONL traces carry the same field names as the CSV headers, one
//! object per line.
//!
//! `time_scale` stretches or compresses arrival times (×0.5 = twice
//! the rate) and `repeat` loops a short trace end to end: each pass is
//! shifted past the previous one by the trace's mean inter-arrival
//! gap, so the spliced stream stays nondecreasing with no thundering
//! herd at the seam.
//!
//! Every row is validated on ingest — non-finite / negative arrivals,
//! zero token counts, and out-of-order rows are rejected with
//! `path:line:`-prefixed errors instead of panicking deep inside the
//! engine (the satellite fix for the old `partial_cmp().unwrap()`
//! NaN panic).

use crate::util::json;
use crate::workload::request::Request;
use crate::workload::store::RequestSource;
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::{BufRead, BufReader, Seek};
use std::path::{Path, PathBuf};

/// Which columns/fields carry arrival time and token counts, and
/// whether arrivals are rebased to the first row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Schema {
    /// `id,arrival_s,prefill_tokens,decode_tokens` — absolute sim
    /// times, replayed as-is.
    Native,
    /// `timestamp,prompt_tokens,output_tokens` — wall-clock stamps,
    /// rebased so the first row arrives at t=0.
    Timestamp,
}

impl Schema {
    fn arrival_key(self) -> &'static str {
        match self {
            Schema::Native => "arrival_s",
            Schema::Timestamp => "timestamp",
        }
    }
    fn prefill_key(self) -> &'static str {
        match self {
            Schema::Native => "prefill_tokens",
            Schema::Timestamp => "prompt_tokens",
        }
    }
    fn decode_key(self) -> &'static str {
        match self {
            Schema::Native => "decode_tokens",
            Schema::Timestamp => "output_tokens",
        }
    }
}

/// One parsed trace row, pre-validation.
#[derive(Debug, Clone, Copy)]
struct RawRow {
    arrival: f64,
    prefill: f64,
    decode: f64,
}

/// Streaming trace-replay [`RequestSource`]. See the module docs for
/// formats and semantics.
pub struct ReplaySource {
    reader: BufReader<File>,
    path: PathBuf,
    schema: Schema,
    jsonl: bool,
    /// CSV column indices for (arrival, prefill, decode).
    csv_cols: (usize, usize, usize),
    time_scale: f64,
    /// Total passes over the file (>= 1).
    repeat: u32,
    pass: u32,
    /// 1-based line number of the line about to be read (for errors).
    line_no: u64,
    /// Rebase origin for [`Schema::Timestamp`] (first row of pass 0).
    base_ts: Option<f64>,
    /// Last *emitted* arrival — monotonicity guard and loop splice
    /// point.
    last_emitted_s: f64,
    /// First and last raw (pre-offset, post-scale) arrivals of the
    /// current pass, for the loop offset.
    pass_first_s: Option<f64>,
    rows_in_pass: u64,
    /// Added to every arrival of the current pass (loop splicing).
    offset_s: f64,
    next_id: u64,
    buf: String,
    done: bool,
}

impl ReplaySource {
    /// Open a trace for replay. `time_scale` multiplies every arrival
    /// time (must be positive and finite); `repeat` is the total number
    /// of passes over the file (>= 1).
    pub fn open(path: impl AsRef<Path>, time_scale: f64, repeat: u32) -> Result<ReplaySource> {
        let path = path.as_ref().to_path_buf();
        if !(time_scale.is_finite() && time_scale > 0.0) {
            bail!("{}: time scale must be positive and finite, got {time_scale}", path.display());
        }
        if repeat == 0 {
            bail!("{}: repeat must be >= 1", path.display());
        }
        let file = File::open(&path).with_context(|| format!("opening trace {}", path.display()))?;
        let mut reader = BufReader::new(file);

        // Sniff the format off the first line, then rewind so row
        // iteration sees a clean stream.
        let mut first = String::new();
        reader
            .read_line(&mut first)
            .with_context(|| format!("reading {}", path.display()))?;
        let head = first.trim();
        if head.is_empty() {
            bail!("{}: empty trace", path.display());
        }
        let jsonl = head.starts_with('{');
        let (schema, csv_cols) = if jsonl {
            let v = json::parse(head).with_context(|| format!("{}:1: bad JSONL row", path.display()))?;
            let schema = if v.get("arrival_s").is_some() {
                Schema::Native
            } else if v.get("timestamp").is_some() {
                Schema::Timestamp
            } else {
                bail!(
                    "{}:1: JSONL trace needs an 'arrival_s' or 'timestamp' field",
                    path.display()
                );
            };
            (schema, (0, 0, 0))
        } else {
            let cols: Vec<&str> = head.split(',').map(str::trim).collect();
            let find = |names: &[&str]| names.iter().find_map(|n| cols.iter().position(|c| c == n));
            let (schema, a) = if let Some(a) = find(&["arrival_s"]) {
                (Schema::Native, a)
            } else if let Some(a) = find(&["timestamp"]) {
                (Schema::Timestamp, a)
            } else {
                bail!(
                    "{}:1: unrecognized trace header '{head}' (need an 'arrival_s' or \
                     'timestamp' column)",
                    path.display()
                );
            };
            let p = find(&["prefill_tokens", "prompt_tokens"]).with_context(|| {
                format!("{}:1: no 'prefill_tokens'/'prompt_tokens' column", path.display())
            })?;
            let d = find(&["decode_tokens", "output_tokens"]).with_context(|| {
                format!("{}:1: no 'decode_tokens'/'output_tokens' column", path.display())
            })?;
            (schema, (a, p, d))
        };

        let mut src = ReplaySource {
            reader,
            path,
            schema,
            jsonl,
            csv_cols,
            time_scale,
            repeat,
            pass: 0,
            line_no: 0,
            base_ts: None,
            last_emitted_s: 0.0,
            pass_first_s: None,
            rows_in_pass: 0,
            offset_s: 0.0,
            next_id: 0,
            buf: String::new(),
            done: false,
        };
        src.rewind()?;
        Ok(src)
    }

    /// Seek back to the first data row (start of a pass).
    fn rewind(&mut self) -> Result<()> {
        self.reader.rewind()?;
        self.line_no = 0;
        self.pass_first_s = None;
        self.rows_in_pass = 0;
        if !self.jsonl {
            // Skip the CSV header.
            self.buf.clear();
            self.reader.read_line(&mut self.buf)?;
            self.line_no = 1;
        }
        Ok(())
    }

    fn row_err(&self, msg: impl std::fmt::Display) -> anyhow::Error {
        anyhow::anyhow!("{}:{}: {msg}", self.path.display(), self.line_no)
    }

    /// Read and parse the next data row of the current pass; `None` at
    /// end of file. Blank lines are skipped.
    fn next_row(&mut self) -> Result<Option<RawRow>> {
        loop {
            self.buf.clear();
            let n = self.reader.read_line(&mut self.buf)?;
            if n == 0 {
                return Ok(None);
            }
            self.line_no += 1;
            let line = self.buf.trim();
            if line.is_empty() {
                continue;
            }
            let row = if self.jsonl {
                let v = json::parse(line).map_err(|e| self.row_err(format!("bad JSONL row: {e}")))?;
                let f = |key: &str| -> Result<f64> {
                    v.get(key)
                        .and_then(|x| x.as_f64())
                        .ok_or_else(|| self.row_err(format!("missing numeric field '{key}'")))
                };
                RawRow {
                    arrival: f(self.schema.arrival_key())?,
                    prefill: f(self.schema.prefill_key())?,
                    decode: f(self.schema.decode_key())?,
                }
            } else {
                let cells: Vec<&str> = line.split(',').map(str::trim).collect();
                let (a, p, d) = self.csv_cols;
                let width = a.max(p).max(d) + 1;
                if cells.len() < width {
                    return Err(self.row_err(format!(
                        "expected at least {width} columns, got {}",
                        cells.len()
                    )));
                }
                let f = |i: usize, what: &str| -> Result<f64> {
                    cells[i]
                        .parse::<f64>()
                        .map_err(|_| self.row_err(format!("bad {what} '{}'", cells[i])))
                };
                RawRow {
                    arrival: f(a, self.schema.arrival_key())?,
                    prefill: f(p, self.schema.prefill_key())?,
                    decode: f(d, self.schema.decode_key())?,
                }
            };
            return Ok(Some(row));
        }
    }

    /// Validate one raw row and turn it into the next emitted request.
    fn emit(&mut self, row: RawRow) -> Result<Request> {
        if !row.arrival.is_finite() {
            return Err(self.row_err(format!("non-finite arrival time {}", row.arrival)));
        }
        if self.schema == Schema::Timestamp && self.base_ts.is_none() {
            self.base_ts = Some(row.arrival);
        }
        let rebased = row.arrival - self.base_ts.unwrap_or(0.0);
        if rebased < 0.0 {
            return Err(self.row_err(format!("negative arrival time {rebased}")));
        }
        let scaled = rebased * self.time_scale;
        match self.pass_first_s {
            None => self.pass_first_s = Some(scaled),
            Some(_) if scaled + self.offset_s < self.last_emitted_s => {
                return Err(self.row_err(format!(
                    "arrival times must be nondecreasing (got {}, previous {})",
                    scaled + self.offset_s,
                    self.last_emitted_s
                )));
            }
            Some(_) => {}
        }
        let tok = |v: f64, what: &str| -> Result<u64> {
            if !v.is_finite() || v < 1.0 {
                Err(self.row_err(format!("{what} must be a finite count >= 1, got {v}")))
            } else {
                Ok(v as u64)
            }
        };
        let prefill = tok(row.prefill, self.schema.prefill_key())?;
        let decode = tok(row.decode, self.schema.decode_key())?;
        let arrival = scaled + self.offset_s;
        self.last_emitted_s = arrival;
        self.rows_in_pass += 1;
        let id = self.next_id;
        self.next_id += 1;
        Ok(Request::new(id, arrival, prefill, decode))
    }

    /// Splice the next pass onto the end of the stream: shift it so
    /// its first arrival lands one mean inter-arrival gap after the
    /// last emitted request.
    fn start_next_pass(&mut self) -> Result<bool> {
        self.pass += 1;
        if self.pass >= self.repeat {
            return Ok(false);
        }
        let span = self.last_emitted_s - self.offset_s - self.pass_first_s.unwrap_or(0.0);
        let mean_gap = span / self.rows_in_pass.saturating_sub(1).max(1) as f64;
        let first = self.pass_first_s.unwrap_or(0.0);
        // offset + first == last_emitted + mean_gap.
        self.offset_s = self.last_emitted_s + mean_gap - first;
        self.rewind()?;
        Ok(true)
    }

    /// Total requests emitted so far.
    pub fn emitted(&self) -> u64 {
        self.next_id
    }

    /// Pull the next request, or a row-numbered error on a malformed
    /// row. [`RequestSource`] is infallible, so the trait impl prints
    /// the error and ends the stream; callers that want the hard error
    /// (the CLI wiring does) should drive this method directly.
    pub fn try_next(&mut self) -> Result<Option<Request>> {
        if self.done {
            return Ok(None);
        }
        loop {
            match self.next_row()? {
                Some(row) => return self.emit(row).map(Some),
                None => {
                    if self.rows_in_pass == 0 {
                        bail!("{}: trace has a header but no data rows", self.path.display());
                    }
                    if !self.start_next_pass()? {
                        self.done = true;
                        return Ok(None);
                    }
                }
            }
        }
    }
}

impl RequestSource for ReplaySource {
    fn next_request(&mut self) -> Option<Request> {
        match self.try_next() {
            Ok(r) => r,
            Err(e) => {
                // The trait is infallible; fail loudly and stop the
                // stream rather than feeding the engine garbage.
                eprintln!("trace replay error: {e:#}");
                self.done = true;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn write_tmp(name: &str, contents: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("vidur_energy_replay_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let mut f = File::create(&path).unwrap();
        f.write_all(contents.as_bytes()).unwrap();
        path
    }

    fn drain(src: &mut ReplaySource) -> Vec<Request> {
        let mut v = Vec::new();
        while let Some(r) = src.try_next().unwrap() {
            v.push(r);
        }
        v
    }

    #[test]
    fn native_csv_replays_verbatim() {
        let p = write_tmp(
            "native.csv",
            "id,arrival_s,prefill_tokens,decode_tokens\n0,0.5,100,20\n1,1.25,80,10\n",
        );
        let mut src = ReplaySource::open(&p, 1.0, 1).unwrap();
        let reqs = drain(&mut src);
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0].arrival_s, 0.5); // not rebased
        assert_eq!(reqs[1].arrival_s, 1.25);
        assert_eq!((reqs[0].prefill_tokens, reqs[0].decode_tokens), (100, 20));
        assert_eq!((reqs[0].id, reqs[1].id), (0, 1));
        assert!(src.try_next().unwrap().is_none(), "exhausted source must stay None");
    }

    #[test]
    fn azure_csv_rebases_to_first_row() {
        let p = write_tmp(
            "azure.csv",
            "timestamp,prompt_tokens,output_tokens\n1000.5,300,40\n1001.0,200,30\n",
        );
        let reqs = drain(&mut ReplaySource::open(&p, 1.0, 1).unwrap());
        assert_eq!(reqs[0].arrival_s, 0.0);
        assert_eq!(reqs[1].arrival_s, 0.5);
        assert_eq!(reqs[1].prefill_tokens, 200);
    }

    #[test]
    fn jsonl_is_sniffed_and_parsed() {
        let p = write_tmp(
            "trace.jsonl",
            "{\"timestamp\": 10.0, \"prompt_tokens\": 64, \"output_tokens\": 8}\n\
             {\"timestamp\": 11.5, \"prompt_tokens\": 32, \"output_tokens\": 4}\n",
        );
        let reqs = drain(&mut ReplaySource::open(&p, 1.0, 1).unwrap());
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[1].arrival_s, 1.5);
        assert_eq!(reqs[1].prefill_tokens, 32);
    }

    #[test]
    fn time_scale_stretches_arrivals() {
        let p = write_tmp(
            "scaled.csv",
            "id,arrival_s,prefill_tokens,decode_tokens\n0,1.0,10,5\n1,3.0,10,5\n",
        );
        let reqs = drain(&mut ReplaySource::open(&p, 2.0, 1).unwrap());
        assert_eq!(reqs[0].arrival_s, 2.0);
        assert_eq!(reqs[1].arrival_s, 6.0);
    }

    #[test]
    fn repeat_splices_monotone_passes() {
        let p = write_tmp(
            "looped.csv",
            "id,arrival_s,prefill_tokens,decode_tokens\n0,0.0,10,5\n1,2.0,20,5\n",
        );
        let reqs = drain(&mut ReplaySource::open(&p, 1.0, 3).unwrap());
        assert_eq!(reqs.len(), 6);
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s, "{reqs:?}");
        }
        // Mean gap = 2.0, so pass 2 starts at 2.0 + 2.0 = 4.0.
        assert_eq!(reqs[2].arrival_s, 4.0);
        assert_eq!(reqs[3].arrival_s, 6.0);
        let ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn malformed_rows_error_with_line_numbers() {
        let nan = write_tmp(
            "nan.csv",
            "id,arrival_s,prefill_tokens,decode_tokens\n0,0.5,10,5\n1,NaN,10,5\n",
        );
        let err = {
            let mut s = ReplaySource::open(&nan, 1.0, 1).unwrap();
            assert!(s.try_next().unwrap().is_some());
            s.try_next().unwrap_err().to_string()
        };
        assert!(err.contains(":3:") && err.contains("non-finite"), "{err}");

        let zero = write_tmp(
            "zero.csv",
            "id,arrival_s,prefill_tokens,decode_tokens\n0,0.5,0,5\n",
        );
        let err = ReplaySource::open(&zero, 1.0, 1)
            .unwrap()
            .try_next()
            .unwrap_err()
            .to_string();
        assert!(err.contains(":2:") && err.contains("prefill_tokens"), "{err}");

        let unsorted = write_tmp(
            "unsorted.csv",
            "id,arrival_s,prefill_tokens,decode_tokens\n0,5.0,10,5\n1,1.0,10,5\n",
        );
        let mut s = ReplaySource::open(&unsorted, 1.0, 1).unwrap();
        assert!(s.try_next().unwrap().is_some());
        let err = s.try_next().unwrap_err().to_string();
        assert!(err.contains("nondecreasing"), "{err}");

        let neg = write_tmp(
            "neg.csv",
            "id,arrival_s,prefill_tokens,decode_tokens\n0,-2.0,10,5\n",
        );
        let err = ReplaySource::open(&neg, 1.0, 1)
            .unwrap()
            .try_next()
            .unwrap_err()
            .to_string();
        assert!(err.contains("negative arrival"), "{err}");
    }

    #[test]
    fn bad_header_and_bad_knobs_rejected() {
        let p = write_tmp("bad_header.csv", "foo,bar\n1,2\n");
        assert!(ReplaySource::open(&p, 1.0, 1).is_err());
        let ok = write_tmp(
            "ok.csv",
            "id,arrival_s,prefill_tokens,decode_tokens\n0,0.0,10,5\n",
        );
        assert!(ReplaySource::open(&ok, 0.0, 1).is_err());
        assert!(ReplaySource::open(&ok, f64::NAN, 1).is_err());
        assert!(ReplaySource::open(&ok, 1.0, 0).is_err());
    }
}
