//! Descriptive statistics used by the metrics / report layers:
//! streaming mean/variance (Welford), exact percentiles, ε-approximate
//! streaming quantiles (Greenwald–Khanna), histograms, and a small
//! linear-regression helper for trend checks in tests.
//!
//! Every accumulator here is **mergeable**: [`Summary::merge`] combines
//! two Welford states exactly (Chan's parallel formula), and
//! [`QuantileSketch::merge`] combines two GK sketches with a documented
//! combined rank-error bound (DESIGN.md §9). Merge is what lets
//! per-shard telemetry from a cross-machine sweep (`repro experiment
//! --shard k/N` … `repro merge`) recombine into one distribution
//! without re-running anything. Both types serialize to the crate's
//! [`crate::util::json::Value`] for the shard telemetry sidecar;
//! floats round-trip bit-exactly (shortest-roundtrip formatting).

use crate::util::json::Value;
use anyhow::Result;
use std::collections::VecDeque;

/// A sliding time window over timestamped samples — the shared shape
/// behind every rolling-window aggregator in the crate (the
/// autoscaler's [`crate::autoscale::CompletionWindow`], the live-watch
/// windows in [`crate::telemetry::window`]).
///
/// Entries are `(t, payload)` pairs appended in stream order;
/// [`TimeWindow::prune`] evicts from the front while the front entry
/// is **strictly older** than `now - window_s`. The retained interval
/// is therefore the *inclusive* `[now - window_s, now]` — an entry
/// whose timestamp lands exactly on the cutoff stays in the window
/// (the convention `CompletionWindow` has always used; pinned by a
/// regression test there).
///
/// Timestamps are expected to be non-decreasing (both the completion
/// stream and the per-replica stage stream satisfy this up to bounded
/// pipeline-stage skew). Eviction stops at the first front entry at or
/// past the cutoff, so the retained set is always a *suffix of the
/// insertion order* — the precise object the windowed-counter property
/// tests recompute against.
#[derive(Debug, Clone)]
pub struct TimeWindow<T> {
    window_s: f64,
    entries: VecDeque<(f64, T)>,
}

impl<T> TimeWindow<T> {
    /// A window spanning the trailing `window_s` seconds (must be > 0).
    pub fn new(window_s: f64) -> Self {
        assert!(window_s > 0.0, "window must be positive");
        TimeWindow {
            window_s,
            entries: VecDeque::new(),
        }
    }

    /// The configured window length, seconds.
    pub fn window_s(&self) -> f64 {
        self.window_s
    }

    /// Append one sample at time `t`.
    pub fn push(&mut self, t: f64, v: T) {
        self.entries.push_back((t, v));
    }

    /// Evict entries strictly older than `now - window_s`.
    pub fn prune(&mut self, now: f64) {
        self.prune_each(now, |_, _| {});
    }

    /// [`TimeWindow::prune`] with an eviction callback — how windowed
    /// accumulators keep incremental counters exact: every quantity
    /// added on `push` is subtracted here when its entry leaves.
    pub fn prune_each(&mut self, now: f64, mut on_evict: impl FnMut(f64, &T)) {
        let cutoff = now - self.window_s;
        while self.entries.front().map(|e| e.0 < cutoff).unwrap_or(false) {
            let (t, v) = self.entries.pop_front().expect("front checked");
            on_evict(t, &v);
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Iterate the retained `(t, payload)` entries, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = (f64, &T)> {
        self.entries.iter().map(|(t, v)| (*t, v))
    }

    /// The averaging span at time `now`: the window length, except
    /// early in a run — before one full window has elapsed — where it
    /// is the elapsed time. The shared divisor every windowed rate
    /// (completions/s, watts) uses.
    pub fn elapsed(&self, now: f64) -> f64 {
        self.window_s.min(now.max(1e-9))
    }

    /// Entries per second over [`TimeWindow::elapsed`].
    pub fn rate(&self, now: f64) -> f64 {
        self.entries.len() as f64 / self.elapsed(now)
    }
}

/// Streaming mean / variance / extrema accumulator (Welford's method).
#[derive(Debug, Clone)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Default for Summary {
    /// Identical to [`Summary::new`]. A derived `Default` would zero
    /// the extrema (`min: 0.0, max: 0.0`), silently pinning `min()` of
    /// any all-positive stream at 0 — the empty accumulator must start
    /// at ±∞ so the first `add`/`merge` sets both.
    fn default() -> Self {
        Self::new()
    }
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum: 0.0,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentile over a sample (linear interpolation between order
/// statistics, matching numpy's default).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    let n = v.len();
    if n == 1 {
        return v[0];
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// One Greenwald–Khanna tuple: a stored sample `v`, the gap `g`
/// between its minimum rank and the previous tuple's, and the rank
/// uncertainty `delta` (r_max = r_min + delta).
#[derive(Debug, Clone, Copy)]
struct GkEntry {
    v: f64,
    g: u64,
    delta: u64,
}

/// ε-approximate streaming quantiles (Greenwald–Khanna, SIGMOD '01).
///
/// **Documented rank-error bound:** after `n` inserts, `quantile(q)`
/// returns a stored sample whose rank in the sorted stream lies within
/// `⌈εn⌉` of the target rank `q·n`. Space is O((1/ε)·log(εn)) tuples —
/// independent of `n` for practical purposes — which is what lets the
/// request-telemetry path keep TTFT/e2e latency distributions for
/// multi-million-request runs without materializing them.
///
/// The structure maintains the GK invariant `g_i + Δ_i ≤ ⌊2εn⌋`
/// (checked in tests). Inserts are O(1) amortized: samples buffer
/// until ⌊1/(2ε)⌋ accumulate, then one sorted-merge + compress pass
/// folds them into the tuple list — never a per-element `Vec::insert`
/// on the hot path.
///
/// Sketches built on different machines (sweep shards) combine with
/// [`QuantileSketch::merge`] and survive disk round-trips through
/// [`QuantileSketch::to_json`] / [`QuantileSketch::from_json`]:
///
/// ```
/// use vidur_energy::util::stats::QuantileSketch;
///
/// // Two shards each see half of a 0..2000 stream.
/// let mut a = QuantileSketch::new(0.01);
/// let mut b = QuantileSketch::new(0.01);
/// for i in 0..1000 {
///     a.add(i as f64);
///     b.add((1000 + i) as f64);
/// }
/// a.merge(&b);
/// assert_eq!(a.count(), 2000);
/// // Rank error stays within ⌈ε·n⌉ = 20 ranks of the true median;
/// // the stream is 1-per-rank, so value error ≤ 20 too.
/// let p50 = a.quantile(0.5).unwrap();
/// assert!((p50 - 1000.0).abs() <= 21.0, "p50 {p50}");
/// // Extremes stay exact through merge + compression.
/// assert_eq!(a.quantile(0.0), Some(0.0));
/// assert_eq!(a.quantile(1.0), Some(1999.0));
/// ```
#[derive(Debug, Clone)]
pub struct QuantileSketch {
    eps: f64,
    entries: Vec<GkEntry>,
    /// Samples folded into `entries` (excludes the buffer).
    n: u64,
    /// Pending samples, folded in batches of `buffer_cap`.
    buffer: Vec<f64>,
    buffer_cap: usize,
}

impl QuantileSketch {
    /// Sketch with relative rank error `eps` (0 < eps < 0.5).
    pub fn new(eps: f64) -> Self {
        assert!(eps > 0.0 && eps < 0.5, "eps must be in (0, 0.5)");
        let buffer_cap = ((1.0 / (2.0 * eps)).floor() as usize).max(1);
        QuantileSketch {
            eps,
            entries: Vec::new(),
            n: 0,
            buffer: Vec::with_capacity(buffer_cap),
            buffer_cap,
        }
    }

    /// The sketch's rank-error parameter ε.
    pub fn epsilon(&self) -> f64 {
        self.eps
    }

    /// Samples inserted so far.
    pub fn count(&self) -> u64 {
        self.n + self.buffer.len() as u64
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Resident tuples + buffered samples — the sketch's whole memory
    /// footprint.
    pub fn resident_tuples(&self) -> usize {
        self.entries.len() + self.buffer.len()
    }

    /// Insert one sample. Non-finite values are rejected (they have no
    /// rank): the caller feeds latencies/delays, which are finite.
    pub fn add(&mut self, v: f64) {
        assert!(v.is_finite(), "QuantileSketch::add({v}): not finite");
        self.buffer.push(v);
        if self.buffer.len() >= self.buffer_cap {
            self.flush();
        }
    }

    /// Fold the buffered samples into the tuple list: sort the batch,
    /// then one merge pass applying the per-sample GK insert rule
    /// (Δ = ⌊2εn⌋ − 1 interior, 0 at the running extremes), then
    /// compress.
    fn flush(&mut self) {
        if self.buffer.is_empty() {
            return;
        }
        let mut buf = std::mem::take(&mut self.buffer);
        buf.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let old = std::mem::take(&mut self.entries);
        let mut out: Vec<GkEntry> = Vec::with_capacity(old.len() + buf.len());
        let mut it_old = old.into_iter().peekable();
        for v in buf {
            self.n += 1;
            while let Some(e) = it_old.peek() {
                if e.v < v {
                    out.push(it_old.next().expect("peeked"));
                } else {
                    break;
                }
            }
            // Position-exact extremes (running min / running max) get
            // Δ = 0; interior inserts carry the standard uncertainty.
            let interior = !out.is_empty() && it_old.peek().is_some();
            let delta = if interior {
                ((2.0 * self.eps * self.n as f64).floor() as u64).saturating_sub(1)
            } else {
                0
            };
            out.push(GkEntry { v, g: 1, delta });
        }
        out.extend(it_old);
        self.entries = out;
        self.compress();
        self.buffer = Vec::with_capacity(self.buffer_cap);
    }

    /// Merge mergeable neighbours in one backward pass, preserving the
    /// stream minimum and maximum tuples.
    fn compress(&mut self) {
        if self.entries.len() <= 2 {
            return;
        }
        let cap = (2.0 * self.eps * self.n as f64).floor() as u64;
        let old = std::mem::take(&mut self.entries);
        let len = old.len();
        let mut rev: Vec<GkEntry> = Vec::with_capacity(len);
        for (k, e) in old.into_iter().rev().enumerate() {
            // k == 0 is the maximum, k == len-1 the minimum: keep both.
            if k == 0 || k == len - 1 {
                rev.push(e);
                continue;
            }
            let nxt = rev.last_mut().expect("max pushed first");
            if e.g + nxt.g + nxt.delta <= cap {
                nxt.g += e.g; // fold e into its right neighbour
            } else {
                rev.push(e);
            }
        }
        rev.reverse();
        self.entries = rev;
    }

    /// A query-ready view: the sketch itself when nothing is buffered,
    /// otherwise a flushed clone — so a caller issuing several
    /// `quantile` queries (e.g. a `stats()` fold) pays for one flush,
    /// not one per query.
    pub fn flushed(&self) -> std::borrow::Cow<'_, QuantileSketch> {
        if self.buffer.is_empty() {
            std::borrow::Cow::Borrowed(self)
        } else {
            let mut c = self.clone();
            c.flush();
            std::borrow::Cow::Owned(c)
        }
    }

    /// The quantile `q` ∈ [0, 1]: a stored sample whose rank is within
    /// `⌈εn⌉` of `q·n`. `None` on an empty sketch. The extremes are
    /// exact: `quantile(0.0)` is the stream minimum, `quantile(1.0)`
    /// the maximum (both tuples survive compression untouched).
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if !self.buffer.is_empty() {
            return self.flushed().quantile(q);
        }
        if self.entries.is_empty() {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        if q == 0.0 {
            return Some(self.entries[0].v);
        }
        if q == 1.0 {
            return Some(self.entries[self.entries.len() - 1].v);
        }
        let target = q * self.n as f64;
        let bound = (self.eps * self.n as f64).ceil();
        let mut rmin = 0u64;
        let mut best = self.entries[0].v;
        let mut best_err = f64::INFINITY;
        for e in &self.entries {
            rmin += e.g;
            let rmax = rmin + e.delta;
            if rmin as f64 >= target - bound && rmax as f64 <= target + bound {
                return Some(e.v);
            }
            // Fallback for tiny n (bound < 1): closest rank midpoint.
            let err = ((rmin + rmax) as f64 / 2.0 - target).abs();
            if err < best_err {
                best_err = err;
                best = e.v;
            }
        }
        Some(best)
    }

    /// Percentile convenience (`p` ∈ [0, 100]), mirroring [`percentile`].
    pub fn percentile(&self, p: f64) -> Option<f64> {
        self.quantile(p / 100.0)
    }

    /// Merge another sketch into this one (standard GK combine +
    /// compress; DESIGN.md §9). The result summarizes the concatenation
    /// of both input streams without re-observing any sample.
    ///
    /// **Combined rank-error bound.** Merging sketches with absolute
    /// rank uncertainties `ε₁n₁` and `ε₂n₂` yields a sketch whose
    /// queries are within `ε₁n₁ + ε₂n₂` ranks of the target over the
    /// `n = n₁ + n₂` combined samples — i.e. an effective
    /// `ε_merged = (ε₁n₁ + ε₂n₂)/n ≤ max(ε₁, ε₂) ≤ ε₁ + ε₂`. In the
    /// usual case of equal-ε shards (the sweep sharding path) the bound
    /// is simply ε again, however many shards are folded in, because
    /// the absolute uncertainties add exactly as the counts do.
    /// [`QuantileSketch::epsilon`] reports the merged effective ε.
    ///
    /// Mechanics: both tuple lists are flushed, merge-sorted by value,
    /// and each tuple's Δ is widened by the rank slack the *other*
    /// sketch contributes at that position (`g + Δ − 1` of the other
    /// side's next tuple) — this keeps every tuple's `[rmin, rmax]`
    /// interval sound for the combined stream, preserving the GK
    /// invariant `g + Δ ≤ 2·ε_merged·n` that `quantile` relies on. The
    /// running minimum and maximum of both inputs survive as the first
    /// and last tuples, so `quantile(0.0)` / `quantile(1.0)` stay
    /// exact. A final compress pass restores O((1/ε)·log(εn)) space.
    pub fn merge(&mut self, other: &QuantileSketch) {
        if other.count() == 0 {
            return;
        }
        if self.count() == 0 {
            *self = other.clone();
            return;
        }
        let a = self.flushed().into_owned();
        let flushed_b;
        let b: &QuantileSketch = if other.buffer.is_empty() {
            other
        } else {
            flushed_b = other.flushed().into_owned();
            &flushed_b
        };

        let n = a.n + b.n;
        let eps = (a.eps * a.n as f64 + b.eps * b.n as f64) / n as f64;
        let mut out: Vec<GkEntry> = Vec::with_capacity(a.entries.len() + b.entries.len());
        let mut ia = a.entries.iter().copied().peekable();
        let mut ib = b.entries.iter().copied().peekable();
        loop {
            match (ia.peek().copied(), ib.peek().copied()) {
                (None, None) => break,
                // Past the other sketch's maximum: it contributes no
                // further rank slack, tuples pass through unchanged.
                (Some(e), None) => {
                    out.push(e);
                    ia.next();
                }
                (None, Some(e)) => {
                    out.push(e);
                    ib.next();
                }
                (Some(ea), Some(eb)) => {
                    // Take the smaller head; widen its Δ by the other
                    // side's local uncertainty (its next tuple's
                    // g + Δ − 1 unresolved ranks).
                    let (mut e, slack) = if ea.v <= eb.v {
                        ia.next();
                        (ea, eb.g + eb.delta)
                    } else {
                        ib.next();
                        (eb, ea.g + ea.delta)
                    };
                    e.delta += slack.saturating_sub(1);
                    out.push(e);
                }
            }
        }

        self.eps = eps;
        self.n = n;
        self.entries = out;
        self.buffer_cap = ((1.0 / (2.0 * eps)).floor() as usize).max(1);
        self.buffer = Vec::with_capacity(self.buffer_cap);
        self.compress();
    }

    /// Serialize the (flushed) sketch for the shard telemetry sidecar:
    /// `{eps, n, entries: [[v, g, delta], …]}`. Floats round-trip
    /// bit-exactly through the crate's JSON writer; `g`/`Δ` are exact
    /// below 2^53.
    pub fn to_json(&self) -> Value {
        let s = self.flushed();
        let mut v = Value::obj();
        let entries: Vec<Value> = s
            .entries
            .iter()
            .map(|e| {
                Value::Arr(vec![
                    Value::Num(e.v),
                    Value::Num(e.g as f64),
                    Value::Num(e.delta as f64),
                ])
            })
            .collect();
        v.set("eps", s.eps)
            .set("n", s.n)
            .set("entries", Value::Arr(entries));
        v
    }

    /// Reload a sketch serialized by [`QuantileSketch::to_json`].
    pub fn from_json(v: &Value) -> Result<QuantileSketch> {
        let eps = v.req_f64("eps")?;
        anyhow::ensure!(eps > 0.0 && eps < 0.5, "sketch eps {eps} outside (0, 0.5)");
        let n = v.req_u64("n")?;
        let raw = v
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| anyhow::anyhow!("sketch missing 'entries' array"))?;
        let mut entries = Vec::with_capacity(raw.len());
        let mut total_g = 0u64;
        let mut prev = f64::NEG_INFINITY;
        for (i, e) in raw.iter().enumerate() {
            let t = e
                .as_arr()
                .filter(|t| t.len() == 3)
                .ok_or_else(|| anyhow::anyhow!("sketch entry {i} is not a [v,g,delta] triple"))?;
            let (v, g, delta) = (
                t[0].as_f64()
                    .ok_or_else(|| anyhow::anyhow!("entry {i}: bad v"))?,
                t[1].as_u64()
                    .ok_or_else(|| anyhow::anyhow!("entry {i}: bad g"))?,
                t[2].as_u64()
                    .ok_or_else(|| anyhow::anyhow!("entry {i}: bad delta"))?,
            );
            anyhow::ensure!(v.is_finite() && v >= prev, "entry {i}: values unsorted");
            anyhow::ensure!(g >= 1, "entry {i}: g must be ≥ 1");
            prev = v;
            total_g += g;
            entries.push(GkEntry { v, g, delta });
        }
        anyhow::ensure!(
            total_g == n,
            "sketch tuple gaps sum to {total_g}, expected n = {n}"
        );
        let buffer_cap = ((1.0 / (2.0 * eps)).floor() as usize).max(1);
        Ok(QuantileSketch {
            eps,
            entries,
            n,
            buffer: Vec::with_capacity(buffer_cap),
            buffer_cap,
        })
    }

    #[cfg(test)]
    fn check_invariant(&self) {
        let mut s = self.clone();
        s.flush();
        let cap = (2.0 * s.eps * s.n as f64).floor() as u64;
        let mut total = 0u64;
        for (i, e) in s.entries.iter().enumerate() {
            total += e.g;
            assert!(
                e.g + e.delta <= cap.max(1),
                "GK invariant violated at tuple {i}: g={} delta={} cap={cap}",
                e.g,
                e.delta
            );
            if i > 0 {
                assert!(s.entries[i - 1].v <= e.v, "entries unsorted");
            }
        }
        assert_eq!(total, s.n, "g's must sum to n");
    }
}

/// Fixed-width histogram.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let nbins = self.counts.len();
            let w = (self.hi - self.lo) / nbins as f64;
            let idx = ((x - self.lo) / w) as usize;
            self.counts[idx.min(nbins - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

/// Ordinary least squares y = a + b*x; returns (intercept, slope, r2).
/// Used by tests to assert e.g. "energy grows linearly with requests".
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { sxy * sxy / (sxx * syy) };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.sum(), 10.0);
    }

    #[test]
    fn summary_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut a = Summary::new();
        let mut b = Summary::new();
        let mut all = Summary::new();
        for (i, x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.add(*x);
            } else {
                b.add(*x);
            }
            all.add(*x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.var() - all.var()).abs() < 1e-9);
    }

    /// Satellite regression: the derived `Default` used to zero the
    /// extrema, so `Summary::default().min()` was pinned at 0.0 for
    /// all-positive streams. `default()` must now be `new()` exactly,
    /// under any interleaving of `add` and `merge`.
    #[test]
    fn summary_default_equals_new_under_add_and_merge() {
        use crate::util::proptest::{check, gens};
        check(60, gens::vec_f64(64, 0.5, 100.0), |xs| {
            let mut via_new = Summary::new();
            let mut via_default = Summary::default();
            // Exercise merge too: fold halves through defaulted accs.
            let mid = xs.len() / 2;
            let mut left = Summary::default();
            let mut right = Summary::default();
            for (i, x) in xs.iter().enumerate() {
                via_new.add(*x);
                via_default.add(*x);
                if i < mid {
                    left.add(*x);
                } else {
                    right.add(*x);
                }
            }
            left.merge(&right);
            for (name, s) in [("add", &via_default), ("merge", &left)] {
                if s.count() != via_new.count()
                    || s.min() != via_new.min()
                    || s.max() != via_new.max()
                    || (s.mean() - via_new.mean()).abs() > 1e-9
                    || (s.var() - via_new.var()).abs() > 1e-6
                {
                    return Err(format!(
                        "default-{name} diverged from new: {s:?} vs {via_new:?}"
                    ));
                }
                if !xs.is_empty() && s.min() <= 0.0 {
                    return Err(format!(
                        "min pinned at {} for positive stream (the old derive bug)",
                        s.min()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn empty_summary_extrema_are_infinite() {
        let d = Summary::default();
        assert_eq!(d.min(), f64::INFINITY);
        assert_eq!(d.max(), f64::NEG_INFINITY);
        assert_eq!(d.count(), 0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn percentile_single_element() {
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    /// The sketch's whole contract: on adversarial input orders the
    /// reported quantile's true rank stays within ⌈εn⌉ (+1 slack for
    /// the interpolation-free answer) of the target rank.
    #[test]
    fn quantile_sketch_rank_error_bounded_on_adversarial_inputs() {
        let eps = 0.01;
        let n = 20_000usize;
        let streams: Vec<(&str, Vec<f64>)> = vec![
            ("ascending", (0..n).map(|i| i as f64).collect()),
            ("descending", (0..n).map(|i| (n - i) as f64).collect()),
            ("constant", vec![42.0; n]),
            (
                "sawtooth",
                (0..n).map(|i| (i % 97) as f64 * 3.5).collect(),
            ),
            (
                "two-spikes",
                (0..n)
                    .map(|i| if i % 2 == 0 { 1.0 } else { 1e6 })
                    .collect(),
            ),
            (
                "zipf-ish tail",
                (0..n).map(|i| 1.0 / (1.0 + (i % 513) as f64)).collect(),
            ),
        ];
        for (name, xs) in &streams {
            let mut sk = QuantileSketch::new(eps);
            for &x in xs {
                sk.add(x);
            }
            sk.check_invariant();
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let slack = (eps * n as f64).ceil() + 1.0;
            for q in [0.0, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
                let v = sk.quantile(q).unwrap();
                // True rank interval of v in the sorted stream.
                let rank_lo = sorted.partition_point(|&x| x < v) as f64;
                let rank_hi = sorted.partition_point(|&x| x <= v) as f64;
                let target = q * n as f64;
                assert!(
                    rank_hi >= target - slack && rank_lo <= target + slack,
                    "{name} q={q}: value {v} has rank [{rank_lo}, {rank_hi}], \
                     target {target} ± {slack}"
                );
            }
            // Space stays sublinear: the memory claim behind streaming
            // request telemetry.
            assert!(
                sk.resident_tuples() < n / 4,
                "{name}: sketch kept {} of {n} samples",
                sk.resident_tuples()
            );
        }
    }

    #[test]
    fn quantile_sketch_small_n_is_exact() {
        let mut sk = QuantileSketch::new(0.01);
        assert_eq!(sk.quantile(0.5), None);
        for x in [5.0, 1.0, 3.0] {
            sk.add(x);
        }
        assert_eq!(sk.quantile(0.0), Some(1.0));
        assert_eq!(sk.quantile(1.0), Some(5.0));
        // Target rank 1.5, bound ⌈εn⌉ = 1: ranks 1 and 2 both satisfy
        // the contract.
        let med = sk.quantile(0.5).unwrap();
        assert!(med == 1.0 || med == 3.0, "median {med}");
        assert_eq!(sk.count(), 3);
        assert_eq!(sk.percentile(100.0), Some(5.0));
    }

    /// The adversarial streams from the insert-path test, re-run
    /// through the shard path: split each stream round-robin across k
    /// shards, sketch each shard independently, fold the shards with
    /// `merge`, and assert the merged sketch still answers within the
    /// documented combined rank error (equal-ε shards ⇒ bound stays
    /// ⌈εn⌉).
    #[test]
    fn merged_shard_sketches_stay_rank_bounded_on_adversarial_inputs() {
        let eps = 0.01;
        let n = 20_000usize;
        let streams: Vec<(&str, Vec<f64>)> = vec![
            ("ascending", (0..n).map(|i| i as f64).collect()),
            ("descending", (0..n).map(|i| (n - i) as f64).collect()),
            ("constant", vec![42.0; n]),
            ("sawtooth", (0..n).map(|i| (i % 97) as f64 * 3.5).collect()),
            (
                "two-spikes",
                (0..n)
                    .map(|i| if i % 2 == 0 { 1.0 } else { 1e6 })
                    .collect(),
            ),
            (
                "zipf-ish tail",
                (0..n).map(|i| 1.0 / (1.0 + (i % 513) as f64)).collect(),
            ),
        ];
        for shards in [2usize, 4] {
            for (name, xs) in &streams {
                let mut parts: Vec<QuantileSketch> =
                    (0..shards).map(|_| QuantileSketch::new(eps)).collect();
                for (i, &x) in xs.iter().enumerate() {
                    parts[i % shards].add(x);
                }
                let mut merged = QuantileSketch::new(eps);
                for p in &parts {
                    merged.merge(p);
                }
                assert_eq!(merged.count(), n as u64);
                merged.check_invariant();
                assert!(
                    (merged.epsilon() - eps).abs() < 1e-12,
                    "{name}: equal-ε shards must merge back to ε, got {}",
                    merged.epsilon()
                );
                let mut sorted = xs.clone();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let slack = (eps * n as f64).ceil() + 1.0;
                for q in [0.0, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
                    let v = merged.quantile(q).unwrap();
                    let rank_lo = sorted.partition_point(|&x| x < v) as f64;
                    let rank_hi = sorted.partition_point(|&x| x <= v) as f64;
                    let target = q * n as f64;
                    assert!(
                        rank_hi >= target - slack && rank_lo <= target + slack,
                        "{name} x{shards} q={q}: value {v} has rank \
                         [{rank_lo}, {rank_hi}], target {target} ± {slack}"
                    );
                }
                assert!(
                    merged.resident_tuples() < n / 4,
                    "{name} x{shards}: merged sketch kept {} of {n}",
                    merged.resident_tuples()
                );
            }
        }
    }

    /// Merge order must not matter beyond the shared bound, and merging
    /// with an empty sketch must be the identity in both directions.
    #[test]
    fn sketch_merge_order_independent_within_bound_and_empty_identity() {
        let eps = 0.02;
        let n = 6_000usize;
        let xs: Vec<f64> = (0..n).map(|i| ((i * 7919) % 10_007) as f64).collect();
        let mut parts: Vec<QuantileSketch> =
            (0..3).map(|_| QuantileSketch::new(eps)).collect();
        for (i, &x) in xs.iter().enumerate() {
            parts[i % 3].add(x);
        }
        let fold = |order: &[usize]| {
            let mut m = QuantileSketch::new(eps);
            for &k in order {
                m.merge(&parts[k]);
            }
            m
        };
        let abc = fold(&[0, 1, 2]);
        let cba = fold(&[2, 1, 0]);
        let bound = 2.0 * (eps * n as f64).ceil() + 2.0; // each answer ±⌈εn⌉ ranks
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.1, 0.5, 0.9, 0.99] {
            let ra = sorted.partition_point(|&x| x < abc.quantile(q).unwrap()) as f64;
            let rb = sorted.partition_point(|&x| x < cba.quantile(q).unwrap()) as f64;
            assert!(
                (ra - rb).abs() <= bound,
                "q={q}: fold orders disagree beyond 2⌈εn⌉: {ra} vs {rb}"
            );
        }
        // Empty in both directions.
        let mut empty = QuantileSketch::new(eps);
        empty.merge(&abc);
        assert_eq!(empty.count(), abc.count());
        assert_eq!(empty.quantile(1.0), abc.quantile(1.0));
        let mut lhs = abc.clone();
        lhs.merge(&QuantileSketch::new(eps));
        assert_eq!(lhs.count(), abc.count());
        assert_eq!(lhs.quantile(0.5), abc.quantile(0.5));
    }

    /// Serialization round-trip is lossless: the reloaded sketch
    /// answers every quantile identically and keeps merging.
    #[test]
    fn sketch_json_roundtrip_is_exact() {
        let mut sk = QuantileSketch::new(0.005);
        for i in 0..5_000 {
            sk.add(((i * 31) % 977) as f64 * 0.125 + 0.1);
        }
        let back = QuantileSketch::from_json(&sk.to_json()).unwrap();
        assert_eq!(back.count(), sk.count());
        assert_eq!(back.epsilon(), sk.epsilon());
        for q in [0.0, 0.01, 0.25, 0.5, 0.75, 0.99, 1.0] {
            assert_eq!(back.quantile(q), sk.quantile(q), "q={q}");
        }
        // Parse back through text too (what the sidecar actually does).
        let text = sk.to_json().pretty();
        let parsed = crate::util::json::parse(&text).unwrap();
        let back2 = QuantileSketch::from_json(&parsed).unwrap();
        assert_eq!(back2.quantile(0.5), sk.quantile(0.5));
        // Corrupt payloads are rejected, not mis-read.
        let mut bad = sk.to_json();
        bad.set("n", 3u64); // no longer matches Σg
        assert!(QuantileSketch::from_json(&bad).is_err());
    }

    /// Satellite property: `Summary::merge` is associative and
    /// order-independent (up to float tolerance) — the guarantee the
    /// shard merge relies on when folding per-shard accumulators in
    /// whatever order the shard dirs are listed.
    #[test]
    fn summary_merge_associative_and_order_independent() {
        use crate::util::proptest::{check, gens};
        check(80, gens::vec_f64(96, -50.0, 50.0), |xs| {
            let third = (xs.len() / 3).max(1);
            let mut parts: Vec<Summary> = Vec::new();
            for chunk in xs.chunks(third) {
                let mut s = Summary::new();
                for &x in chunk {
                    s.add(x);
                }
                parts.push(s);
            }
            let fold = |order: Vec<usize>| {
                let mut acc = Summary::new();
                for i in order {
                    acc.merge(&parts[i]);
                }
                acc
            };
            let fwd = fold((0..parts.len()).collect());
            let rev = fold((0..parts.len()).rev().collect());
            // Right-nested association: merge the tail first.
            let mut tail = Summary::new();
            for p in parts.iter().skip(1).rev() {
                let mut t = p.clone();
                t.merge(&tail);
                tail = t;
            }
            let mut nested = parts[0].clone();
            nested.merge(&tail);
            for (name, s) in [("reversed", &rev), ("nested", &nested)] {
                if s.count() != fwd.count()
                    || s.min() != fwd.min()
                    || s.max() != fwd.max()
                    || (s.sum() - fwd.sum()).abs() > 1e-9 * (1.0 + fwd.sum().abs())
                    || (s.mean() - fwd.mean()).abs() > 1e-9
                    || (s.var() - fwd.var()).abs() > 1e-6
                {
                    return Err(format!("{name} fold diverged: {s:?} vs {fwd:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn quantile_sketch_extremes_survive_compression() {
        let mut sk = QuantileSketch::new(0.05);
        for i in 0..10_000 {
            sk.add((i % 1000) as f64);
        }
        assert_eq!(sk.quantile(0.0), Some(0.0));
        assert_eq!(sk.quantile(1.0), Some(999.0));
    }

    /// The shared window's eviction semantics, pinned: retained ⇔
    /// `t ≥ now − window` (inclusive cutoff), suffix-of-insertion
    /// order, and the incremental-counter contract of `prune_each`.
    #[test]
    fn time_window_prunes_inclusive_cutoff_suffix() {
        let mut w: TimeWindow<u64> = TimeWindow::new(10.0);
        for i in 0..6u64 {
            w.push(i as f64 * 5.0, i); // t = 0, 5, 10, 15, 20, 25
        }
        let mut evicted = Vec::new();
        // cutoff = 10: t = 0, 5 evicted; t = 10 exactly is retained.
        w.prune_each(20.0, |t, &v| evicted.push((t, v)));
        assert_eq!(evicted, vec![(0.0, 0), (5.0, 1)]);
        assert_eq!(w.len(), 4);
        let kept: Vec<f64> = w.iter().map(|(t, _)| t).collect();
        assert_eq!(kept, vec![10.0, 15.0, 20.0, 25.0]);
        // rate: 4 entries over a full window.
        assert!((w.rate(20.0) - 0.4).abs() < 1e-12);
        // Early-window rate divides by elapsed time, not window length.
        let mut early: TimeWindow<()> = TimeWindow::new(100.0);
        early.push(1.0, ());
        assert!((early.rate(4.0) - 0.25).abs() < 1e-12);
        // Empty-window cases.
        let mut empty: TimeWindow<()> = TimeWindow::new(5.0);
        assert!(empty.is_empty());
        empty.prune(1e9); // no-op
        assert_eq!(empty.len(), 0);
        assert_eq!(empty.rate(10.0), 0.0);
    }

    /// Property: for random monotone streams and window sizes, a
    /// counter maintained incrementally through `push`/`prune_each`
    /// equals an exact recompute over the retained suffix after every
    /// step (single-event streams included via the generator's n = 1).
    #[test]
    fn time_window_incremental_equals_retained_recompute() {
        use crate::util::proptest::{check, gens};
        check(80, gens::vec_f64(64, 0.01, 7.0), |dts| {
            for window_s in [0.5, 3.0, 25.0] {
                let mut w: TimeWindow<f64> = TimeWindow::new(window_s);
                let mut sum = 0.0f64;
                let mut t = 0.0f64;
                for (i, dt) in dts.iter().enumerate() {
                    t += dt;
                    let v = (i as f64).sin() * 10.0 + 11.0;
                    w.push(t, v);
                    sum += v;
                    w.prune_each(t, |_, x| sum -= x);
                    let exact: f64 = w.iter().map(|(_, x)| *x).sum();
                    if (sum - exact).abs() > 1e-9 {
                        return Err(format!(
                            "incremental {sum} != retained {exact} at step {i}, window {window_s}"
                        ));
                    }
                    if w.iter().any(|(ts, _)| ts < t - window_s) {
                        return Err(format!("stale entry survived prune at t={t}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn histogram_bins() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(10.0);
        assert!(h.counts.iter().all(|&c| c == 1));
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn linreg_exact_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linreg_noisy_r2_below_one() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| x + if (*x as u64) % 2 == 0 { 5.0 } else { -5.0 })
            .collect();
        let (_, b, r2) = linreg(&xs, &ys);
        assert!(b > 0.8 && b < 1.2);
        assert!(r2 < 1.0);
    }
}
