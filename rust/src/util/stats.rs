//! Descriptive statistics used by the metrics / report layers:
//! streaming mean/variance (Welford), exact percentiles, histograms,
//! and a small linear-regression helper for trend checks in tests.

/// Streaming mean / variance / extrema accumulator (Welford's method).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum: 0.0,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentile over a sample (linear interpolation between order
/// statistics, matching numpy's default).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    let n = v.len();
    if n == 1 {
        return v[0];
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// Fixed-width histogram.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let nbins = self.counts.len();
            let w = (self.hi - self.lo) / nbins as f64;
            let idx = ((x - self.lo) / w) as usize;
            self.counts[idx.min(nbins - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

/// Ordinary least squares y = a + b*x; returns (intercept, slope, r2).
/// Used by tests to assert e.g. "energy grows linearly with requests".
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { sxy * sxy / (sxx * syy) };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.sum(), 10.0);
    }

    #[test]
    fn summary_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut a = Summary::new();
        let mut b = Summary::new();
        let mut all = Summary::new();
        for (i, x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.add(*x);
            } else {
                b.add(*x);
            }
            all.add(*x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.var() - all.var()).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn percentile_single_element() {
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn histogram_bins() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(10.0);
        assert!(h.counts.iter().all(|&c| c == 1));
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn linreg_exact_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linreg_noisy_r2_below_one() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| x + if (*x as u64) % 2 == 0 { 5.0 } else { -5.0 })
            .collect();
        let (_, b, r2) = linreg(&xs, &ys);
        assert!(b > 0.8 && b < 1.2);
        assert!(r2 < 1.0);
    }
}
