//! Descriptive statistics used by the metrics / report layers:
//! streaming mean/variance (Welford), exact percentiles, ε-approximate
//! streaming quantiles (Greenwald–Khanna), histograms, and a small
//! linear-regression helper for trend checks in tests.

/// Streaming mean / variance / extrema accumulator (Welford's method).
#[derive(Debug, Clone)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Default for Summary {
    /// Identical to [`Summary::new`]. A derived `Default` would zero
    /// the extrema (`min: 0.0, max: 0.0`), silently pinning `min()` of
    /// any all-positive stream at 0 — the empty accumulator must start
    /// at ±∞ so the first `add`/`merge` sets both.
    fn default() -> Self {
        Self::new()
    }
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum: 0.0,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentile over a sample (linear interpolation between order
/// statistics, matching numpy's default).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    let n = v.len();
    if n == 1 {
        return v[0];
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// One Greenwald–Khanna tuple: a stored sample `v`, the gap `g`
/// between its minimum rank and the previous tuple's, and the rank
/// uncertainty `delta` (r_max = r_min + delta).
#[derive(Debug, Clone, Copy)]
struct GkEntry {
    v: f64,
    g: u64,
    delta: u64,
}

/// ε-approximate streaming quantiles (Greenwald–Khanna, SIGMOD '01).
///
/// **Documented rank-error bound:** after `n` inserts, `quantile(q)`
/// returns a stored sample whose rank in the sorted stream lies within
/// `⌈εn⌉` of the target rank `q·n`. Space is O((1/ε)·log(εn)) tuples —
/// independent of `n` for practical purposes — which is what lets the
/// request-telemetry path keep TTFT/e2e latency distributions for
/// multi-million-request runs without materializing them.
///
/// The structure maintains the GK invariant `g_i + Δ_i ≤ ⌊2εn⌋`
/// (checked in tests). Inserts are O(1) amortized: samples buffer
/// until ⌊1/(2ε)⌋ accumulate, then one sorted-merge + compress pass
/// folds them into the tuple list — never a per-element `Vec::insert`
/// on the hot path.
#[derive(Debug, Clone)]
pub struct QuantileSketch {
    eps: f64,
    entries: Vec<GkEntry>,
    /// Samples folded into `entries` (excludes the buffer).
    n: u64,
    /// Pending samples, folded in batches of `buffer_cap`.
    buffer: Vec<f64>,
    buffer_cap: usize,
}

impl QuantileSketch {
    /// Sketch with relative rank error `eps` (0 < eps < 0.5).
    pub fn new(eps: f64) -> Self {
        assert!(eps > 0.0 && eps < 0.5, "eps must be in (0, 0.5)");
        let buffer_cap = ((1.0 / (2.0 * eps)).floor() as usize).max(1);
        QuantileSketch {
            eps,
            entries: Vec::new(),
            n: 0,
            buffer: Vec::with_capacity(buffer_cap),
            buffer_cap,
        }
    }

    /// The sketch's rank-error parameter ε.
    pub fn epsilon(&self) -> f64 {
        self.eps
    }

    /// Samples inserted so far.
    pub fn count(&self) -> u64 {
        self.n + self.buffer.len() as u64
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Resident tuples + buffered samples — the sketch's whole memory
    /// footprint.
    pub fn resident_tuples(&self) -> usize {
        self.entries.len() + self.buffer.len()
    }

    /// Insert one sample. Non-finite values are rejected (they have no
    /// rank): the caller feeds latencies/delays, which are finite.
    pub fn add(&mut self, v: f64) {
        assert!(v.is_finite(), "QuantileSketch::add({v}): not finite");
        self.buffer.push(v);
        if self.buffer.len() >= self.buffer_cap {
            self.flush();
        }
    }

    /// Fold the buffered samples into the tuple list: sort the batch,
    /// then one merge pass applying the per-sample GK insert rule
    /// (Δ = ⌊2εn⌋ − 1 interior, 0 at the running extremes), then
    /// compress.
    fn flush(&mut self) {
        if self.buffer.is_empty() {
            return;
        }
        let mut buf = std::mem::take(&mut self.buffer);
        buf.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let old = std::mem::take(&mut self.entries);
        let mut out: Vec<GkEntry> = Vec::with_capacity(old.len() + buf.len());
        let mut it_old = old.into_iter().peekable();
        for v in buf {
            self.n += 1;
            while let Some(e) = it_old.peek() {
                if e.v < v {
                    out.push(it_old.next().expect("peeked"));
                } else {
                    break;
                }
            }
            // Position-exact extremes (running min / running max) get
            // Δ = 0; interior inserts carry the standard uncertainty.
            let interior = !out.is_empty() && it_old.peek().is_some();
            let delta = if interior {
                ((2.0 * self.eps * self.n as f64).floor() as u64).saturating_sub(1)
            } else {
                0
            };
            out.push(GkEntry { v, g: 1, delta });
        }
        out.extend(it_old);
        self.entries = out;
        self.compress();
        self.buffer = Vec::with_capacity(self.buffer_cap);
    }

    /// Merge mergeable neighbours in one backward pass, preserving the
    /// stream minimum and maximum tuples.
    fn compress(&mut self) {
        if self.entries.len() <= 2 {
            return;
        }
        let cap = (2.0 * self.eps * self.n as f64).floor() as u64;
        let old = std::mem::take(&mut self.entries);
        let len = old.len();
        let mut rev: Vec<GkEntry> = Vec::with_capacity(len);
        for (k, e) in old.into_iter().rev().enumerate() {
            // k == 0 is the maximum, k == len-1 the minimum: keep both.
            if k == 0 || k == len - 1 {
                rev.push(e);
                continue;
            }
            let nxt = rev.last_mut().expect("max pushed first");
            if e.g + nxt.g + nxt.delta <= cap {
                nxt.g += e.g; // fold e into its right neighbour
            } else {
                rev.push(e);
            }
        }
        rev.reverse();
        self.entries = rev;
    }

    /// A query-ready view: the sketch itself when nothing is buffered,
    /// otherwise a flushed clone — so a caller issuing several
    /// `quantile` queries (e.g. a `stats()` fold) pays for one flush,
    /// not one per query.
    pub fn flushed(&self) -> std::borrow::Cow<'_, QuantileSketch> {
        if self.buffer.is_empty() {
            std::borrow::Cow::Borrowed(self)
        } else {
            let mut c = self.clone();
            c.flush();
            std::borrow::Cow::Owned(c)
        }
    }

    /// The quantile `q` ∈ [0, 1]: a stored sample whose rank is within
    /// `⌈εn⌉` of `q·n`. `None` on an empty sketch. The extremes are
    /// exact: `quantile(0.0)` is the stream minimum, `quantile(1.0)`
    /// the maximum (both tuples survive compression untouched).
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if !self.buffer.is_empty() {
            return self.flushed().quantile(q);
        }
        if self.entries.is_empty() {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        if q == 0.0 {
            return Some(self.entries[0].v);
        }
        if q == 1.0 {
            return Some(self.entries[self.entries.len() - 1].v);
        }
        let target = q * self.n as f64;
        let bound = (self.eps * self.n as f64).ceil();
        let mut rmin = 0u64;
        let mut best = self.entries[0].v;
        let mut best_err = f64::INFINITY;
        for e in &self.entries {
            rmin += e.g;
            let rmax = rmin + e.delta;
            if rmin as f64 >= target - bound && rmax as f64 <= target + bound {
                return Some(e.v);
            }
            // Fallback for tiny n (bound < 1): closest rank midpoint.
            let err = ((rmin + rmax) as f64 / 2.0 - target).abs();
            if err < best_err {
                best_err = err;
                best = e.v;
            }
        }
        Some(best)
    }

    /// Percentile convenience (`p` ∈ [0, 100]), mirroring [`percentile`].
    pub fn percentile(&self, p: f64) -> Option<f64> {
        self.quantile(p / 100.0)
    }

    #[cfg(test)]
    fn check_invariant(&self) {
        let mut s = self.clone();
        s.flush();
        let cap = (2.0 * s.eps * s.n as f64).floor() as u64;
        let mut total = 0u64;
        for (i, e) in s.entries.iter().enumerate() {
            total += e.g;
            assert!(
                e.g + e.delta <= cap.max(1),
                "GK invariant violated at tuple {i}: g={} delta={} cap={cap}",
                e.g,
                e.delta
            );
            if i > 0 {
                assert!(s.entries[i - 1].v <= e.v, "entries unsorted");
            }
        }
        assert_eq!(total, s.n, "g's must sum to n");
    }
}

/// Fixed-width histogram.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let nbins = self.counts.len();
            let w = (self.hi - self.lo) / nbins as f64;
            let idx = ((x - self.lo) / w) as usize;
            self.counts[idx.min(nbins - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

/// Ordinary least squares y = a + b*x; returns (intercept, slope, r2).
/// Used by tests to assert e.g. "energy grows linearly with requests".
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { sxy * sxy / (sxx * syy) };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.sum(), 10.0);
    }

    #[test]
    fn summary_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut a = Summary::new();
        let mut b = Summary::new();
        let mut all = Summary::new();
        for (i, x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.add(*x);
            } else {
                b.add(*x);
            }
            all.add(*x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.var() - all.var()).abs() < 1e-9);
    }

    /// Satellite regression: the derived `Default` used to zero the
    /// extrema, so `Summary::default().min()` was pinned at 0.0 for
    /// all-positive streams. `default()` must now be `new()` exactly,
    /// under any interleaving of `add` and `merge`.
    #[test]
    fn summary_default_equals_new_under_add_and_merge() {
        use crate::util::proptest::{check, gens};
        check(60, gens::vec_f64(64, 0.5, 100.0), |xs| {
            let mut via_new = Summary::new();
            let mut via_default = Summary::default();
            // Exercise merge too: fold halves through defaulted accs.
            let mid = xs.len() / 2;
            let mut left = Summary::default();
            let mut right = Summary::default();
            for (i, x) in xs.iter().enumerate() {
                via_new.add(*x);
                via_default.add(*x);
                if i < mid {
                    left.add(*x);
                } else {
                    right.add(*x);
                }
            }
            left.merge(&right);
            for (name, s) in [("add", &via_default), ("merge", &left)] {
                if s.count() != via_new.count()
                    || s.min() != via_new.min()
                    || s.max() != via_new.max()
                    || (s.mean() - via_new.mean()).abs() > 1e-9
                    || (s.var() - via_new.var()).abs() > 1e-6
                {
                    return Err(format!(
                        "default-{name} diverged from new: {s:?} vs {via_new:?}"
                    ));
                }
                if !xs.is_empty() && s.min() <= 0.0 {
                    return Err(format!(
                        "min pinned at {} for positive stream (the old derive bug)",
                        s.min()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn empty_summary_extrema_are_infinite() {
        let d = Summary::default();
        assert_eq!(d.min(), f64::INFINITY);
        assert_eq!(d.max(), f64::NEG_INFINITY);
        assert_eq!(d.count(), 0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn percentile_single_element() {
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    /// The sketch's whole contract: on adversarial input orders the
    /// reported quantile's true rank stays within ⌈εn⌉ (+1 slack for
    /// the interpolation-free answer) of the target rank.
    #[test]
    fn quantile_sketch_rank_error_bounded_on_adversarial_inputs() {
        let eps = 0.01;
        let n = 20_000usize;
        let streams: Vec<(&str, Vec<f64>)> = vec![
            ("ascending", (0..n).map(|i| i as f64).collect()),
            ("descending", (0..n).map(|i| (n - i) as f64).collect()),
            ("constant", vec![42.0; n]),
            (
                "sawtooth",
                (0..n).map(|i| (i % 97) as f64 * 3.5).collect(),
            ),
            (
                "two-spikes",
                (0..n)
                    .map(|i| if i % 2 == 0 { 1.0 } else { 1e6 })
                    .collect(),
            ),
            (
                "zipf-ish tail",
                (0..n).map(|i| 1.0 / (1.0 + (i % 513) as f64)).collect(),
            ),
        ];
        for (name, xs) in &streams {
            let mut sk = QuantileSketch::new(eps);
            for &x in xs {
                sk.add(x);
            }
            sk.check_invariant();
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let slack = (eps * n as f64).ceil() + 1.0;
            for q in [0.0, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
                let v = sk.quantile(q).unwrap();
                // True rank interval of v in the sorted stream.
                let rank_lo = sorted.partition_point(|&x| x < v) as f64;
                let rank_hi = sorted.partition_point(|&x| x <= v) as f64;
                let target = q * n as f64;
                assert!(
                    rank_hi >= target - slack && rank_lo <= target + slack,
                    "{name} q={q}: value {v} has rank [{rank_lo}, {rank_hi}], \
                     target {target} ± {slack}"
                );
            }
            // Space stays sublinear: the memory claim behind streaming
            // request telemetry.
            assert!(
                sk.resident_tuples() < n / 4,
                "{name}: sketch kept {} of {n} samples",
                sk.resident_tuples()
            );
        }
    }

    #[test]
    fn quantile_sketch_small_n_is_exact() {
        let mut sk = QuantileSketch::new(0.01);
        assert_eq!(sk.quantile(0.5), None);
        for x in [5.0, 1.0, 3.0] {
            sk.add(x);
        }
        assert_eq!(sk.quantile(0.0), Some(1.0));
        assert_eq!(sk.quantile(1.0), Some(5.0));
        // Target rank 1.5, bound ⌈εn⌉ = 1: ranks 1 and 2 both satisfy
        // the contract.
        let med = sk.quantile(0.5).unwrap();
        assert!(med == 1.0 || med == 3.0, "median {med}");
        assert_eq!(sk.count(), 3);
        assert_eq!(sk.percentile(100.0), Some(5.0));
    }

    #[test]
    fn quantile_sketch_extremes_survive_compression() {
        let mut sk = QuantileSketch::new(0.05);
        for i in 0..10_000 {
            sk.add((i % 1000) as f64);
        }
        assert_eq!(sk.quantile(0.0), Some(0.0));
        assert_eq!(sk.quantile(1.0), Some(999.0));
    }

    #[test]
    fn histogram_bins() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(10.0);
        assert!(h.counts.iter().all(|&c| c == 1));
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn linreg_exact_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linreg_noisy_r2_below_one() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| x + if (*x as u64) % 2 == 0 { 5.0 } else { -5.0 })
            .collect();
        let (_, b, r2) = linreg(&xs, &ys);
        assert!(b > 0.8 && b < 1.2);
        assert!(r2 < 1.0);
    }
}
