//! Time-series container with the interpolation/resampling operations
//! the Vessim-side signals need (the paper resamples Solcast/WattTime
//! with cubic interpolation to the co-simulation resolution).
//!
//! Implements linear and monotone-cubic (PCHIP, Fritsch–Carlson)
//! interpolation — PCHIP rather than a natural cubic spline because
//! irradiance/carbon-intensity traces must not overshoot (no negative
//! solar power from interpolation artifacts).

/// A strictly-time-ordered series of (t_seconds, value) samples.
#[derive(Debug, Clone)]
pub struct TimeSeries {
    t: Vec<f64>,
    v: Vec<f64>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Interp {
    /// Piecewise-constant (previous value) — Vessim's default for
    /// load profiles.
    Step,
    Linear,
    /// Monotone cubic (PCHIP); shape-preserving, no overshoot.
    Cubic,
}

impl TimeSeries {
    pub fn new(t: Vec<f64>, v: Vec<f64>) -> Self {
        assert_eq!(t.len(), v.len(), "time/value length mismatch");
        assert!(!t.is_empty(), "empty time series");
        assert!(
            t.windows(2).all(|w| w[0] < w[1]),
            "timestamps must be strictly increasing"
        );
        TimeSeries { t, v }
    }

    pub fn len(&self) -> usize {
        self.t.len()
    }
    pub fn is_empty(&self) -> bool {
        false // constructor forbids empty
    }
    pub fn times(&self) -> &[f64] {
        &self.t
    }
    pub fn values(&self) -> &[f64] {
        &self.v
    }
    pub fn start(&self) -> f64 {
        self.t[0]
    }
    pub fn end(&self) -> f64 {
        *self.t.last().unwrap()
    }

    /// Index of the last sample with t <= query (None if before start).
    fn locate(&self, t: f64) -> Option<usize> {
        if t < self.t[0] {
            return None;
        }
        let mut lo = 0usize;
        let mut hi = self.t.len();
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if self.t[mid] <= t {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        Some(lo)
    }

    /// Sample at time `t` with the given interpolation. Clamps outside
    /// the covered range (held at the boundary values).
    pub fn at(&self, t: f64, interp: Interp) -> f64 {
        let n = self.t.len();
        match self.locate(t) {
            None => self.v[0],
            Some(i) if i + 1 >= n => self.v[n - 1],
            Some(i) => {
                let (t0, t1) = (self.t[i], self.t[i + 1]);
                let (y0, y1) = (self.v[i], self.v[i + 1]);
                match interp {
                    Interp::Step => y0,
                    Interp::Linear => {
                        let a = (t - t0) / (t1 - t0);
                        y0 + a * (y1 - y0)
                    }
                    Interp::Cubic => {
                        let (d0, d1) = self.pchip_slopes(i);
                        let h = t1 - t0;
                        let s = (t - t0) / h;
                        let s2 = s * s;
                        let s3 = s2 * s;
                        let h00 = 2.0 * s3 - 3.0 * s2 + 1.0;
                        let h10 = s3 - 2.0 * s2 + s;
                        let h01 = -2.0 * s3 + 3.0 * s2;
                        let h11 = s3 - s2;
                        h00 * y0 + h10 * h * d0 + h01 * y1 + h11 * h * d1
                    }
                }
            }
        }
    }

    /// Fritsch–Carlson monotone slopes at segment i's endpoints.
    fn pchip_slopes(&self, i: usize) -> (f64, f64) {
        let n = self.t.len();
        let delta = |k: usize| (self.v[k + 1] - self.v[k]) / (self.t[k + 1] - self.t[k]);
        let slope_at = |k: usize| -> f64 {
            if k == 0 {
                delta(0)
            } else if k == n - 1 {
                delta(n - 2)
            } else {
                let d0 = delta(k - 1);
                let d1 = delta(k);
                if d0 * d1 <= 0.0 {
                    0.0 // local extremum: flat tangent preserves monotonicity
                } else {
                    // Weighted harmonic mean (Fritsch–Butland variant).
                    let h0 = self.t[k] - self.t[k - 1];
                    let h1 = self.t[k + 1] - self.t[k];
                    let w1 = 2.0 * h1 + h0;
                    let w2 = h1 + 2.0 * h0;
                    (w1 + w2) / (w1 / d0 + w2 / d1)
                }
            }
        };
        (slope_at(i), slope_at(i + 1))
    }

    /// Resample onto a fixed grid `[start, end)` with step `dt`.
    pub fn resample(&self, start: f64, end: f64, dt: f64, interp: Interp) -> TimeSeries {
        assert!(dt > 0.0 && end > start);
        let n = ((end - start) / dt).ceil() as usize;
        let t: Vec<f64> = (0..n).map(|i| start + i as f64 * dt).collect();
        let v: Vec<f64> = t.iter().map(|&ti| self.at(ti, interp)).collect();
        TimeSeries::new(t, v)
    }

    /// Mean value over `[a, b]` by trapezoidal integration of the
    /// linear interpolant (used in energy summaries).
    pub fn mean_over(&self, a: f64, b: f64, samples: usize) -> f64 {
        assert!(b > a && samples >= 2);
        let dt = (b - a) / (samples - 1) as f64;
        let mut acc = 0.0;
        for i in 0..samples {
            let w = if i == 0 || i == samples - 1 { 0.5 } else { 1.0 };
            acc += w * self.at(a + i as f64 * dt, Interp::Linear);
        }
        acc / (samples - 1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ts() -> TimeSeries {
        TimeSeries::new(vec![0.0, 1.0, 2.0, 3.0], vec![0.0, 10.0, 10.0, 0.0])
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn rejects_unordered() {
        TimeSeries::new(vec![0.0, 0.0], vec![1.0, 2.0]);
    }

    #[test]
    fn step_holds_previous() {
        let s = ts();
        assert_eq!(s.at(0.5, Interp::Step), 0.0);
        assert_eq!(s.at(1.0, Interp::Step), 10.0);
        assert_eq!(s.at(1.99, Interp::Step), 10.0);
    }

    #[test]
    fn linear_midpoints() {
        let s = ts();
        assert!((s.at(0.5, Interp::Linear) - 5.0).abs() < 1e-12);
        assert!((s.at(2.5, Interp::Linear) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn clamps_out_of_range() {
        let s = ts();
        for interp in [Interp::Step, Interp::Linear, Interp::Cubic] {
            assert_eq!(s.at(-5.0, interp), 0.0);
            assert_eq!(s.at(99.0, interp), 0.0);
        }
    }

    #[test]
    fn cubic_hits_knots() {
        let s = ts();
        for (i, &t) in s.times().iter().enumerate() {
            assert!((s.at(t, Interp::Cubic) - s.values()[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn cubic_no_overshoot_on_plateau() {
        // PCHIP must not overshoot above the plateau value of 10.
        let s = ts();
        for k in 0..100 {
            let t = 0.0 + 3.0 * k as f64 / 99.0;
            let y = s.at(t, Interp::Cubic);
            assert!(
                y <= 10.0 + 1e-9 && y >= -1e-9,
                "overshoot at t={t}: {y}"
            );
        }
    }

    #[test]
    fn cubic_monotone_on_monotone_data() {
        let s = TimeSeries::new(
            vec![0.0, 1.0, 2.0, 3.0, 4.0],
            vec![0.0, 1.0, 4.0, 9.0, 16.0],
        );
        let mut prev = -1.0;
        for k in 0..200 {
            let t = 4.0 * k as f64 / 199.0;
            let y = s.at(t, Interp::Cubic);
            assert!(y >= prev - 1e-9, "non-monotone at {t}");
            prev = y;
        }
    }

    #[test]
    fn resample_grid() {
        let s = ts();
        let r = s.resample(0.0, 3.0, 0.5, Interp::Linear);
        assert_eq!(r.len(), 6);
        assert!((r.values()[1] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn mean_over_trapezoid() {
        let s = TimeSeries::new(vec![0.0, 10.0], vec![0.0, 10.0]);
        let m = s.mean_over(0.0, 10.0, 101);
        assert!((m - 5.0).abs() < 1e-9, "mean {m}");
    }
}
