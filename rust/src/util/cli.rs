//! Tiny CLI argument parser (clap is unavailable offline): subcommands,
//! `--flag value` / `--flag=value` options, boolean switches, typed
//! accessors with defaults, and generated usage text.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Declarative spec of one option for help text.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// Parsed arguments: positionals + `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse a raw argument list. `--key=value` and `--key value` both
    /// work; `--key` followed by another `--…` (or nothing) is a switch.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.options.insert(body.to_string(), v);
                        }
                        _ => out.switches.push(body.to_string()),
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .with_context(|| format!("--{key} expects a number, got '{s}'")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => parse_u64_friendly(s)
                .with_context(|| format!("--{key} expects an integer, got '{s}'")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(key, default as u64)? as usize)
    }

    /// Reject unknown options (catch typos early).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys().chain(self.switches.iter()) {
            if !known.contains(&k.as_str()) {
                bail!(
                    "unknown option --{k}; known: {}",
                    known.iter().map(|s| format!("--{s}")).collect::<Vec<_>>().join(", ")
                );
            }
        }
        Ok(())
    }
}

/// Accept "65536", "2^16", "64k", "2M".
pub fn parse_u64_friendly(s: &str) -> Result<u64> {
    let s = s.trim();
    if let Some((base, exp)) = s.split_once('^') {
        let b: u64 = base.trim().parse()?;
        let e: u32 = exp.trim().parse()?;
        return Ok(b.pow(e));
    }
    if let Some(k) = s.strip_suffix(['k', 'K']) {
        return Ok(k.trim().parse::<u64>()? * 1000);
    }
    if let Some(m) = s.strip_suffix(['m', 'M']) {
        return Ok(m.trim().parse::<u64>()? * 1_000_000);
    }
    Ok(s.parse()?)
}

/// Render usage text for a subcommand.
pub fn usage(cmd: &str, about: &str, opts: &[OptSpec]) -> String {
    let mut s = format!("{cmd} — {about}\n\noptions:\n");
    for o in opts {
        let def = o
            .default
            .map(|d| format!(" (default: {d})"))
            .unwrap_or_default();
        s.push_str(&format!("  --{:<22} {}{}\n", o.name, o.help, def));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let a = args(&["experiment", "exp1", "--qps", "6.45", "--out=results"]);
        assert_eq!(a.positional, vec!["experiment", "exp1"]);
        assert_eq!(a.get("qps"), Some("6.45"));
        assert_eq!(a.get("out"), Some("results"));
    }

    #[test]
    fn switches() {
        let a = args(&["--verbose", "--n", "3"]);
        assert!(a.has("verbose"));
        assert_eq!(a.u64_or("n", 0).unwrap(), 3);
    }

    #[test]
    fn typed_defaults() {
        let a = args(&[]);
        assert_eq!(a.f64_or("qps", 6.45).unwrap(), 6.45);
        assert_eq!(a.str_or("model", "llama3-8b"), "llama3-8b");
    }

    #[test]
    fn typed_errors() {
        let a = args(&["--qps", "abc"]);
        assert!(a.f64_or("qps", 1.0).is_err());
    }

    #[test]
    fn friendly_ints() {
        assert_eq!(parse_u64_friendly("2^16").unwrap(), 65536);
        assert_eq!(parse_u64_friendly("400k").unwrap(), 400_000);
        assert_eq!(parse_u64_friendly("2M").unwrap(), 2_000_000);
        assert_eq!(parse_u64_friendly("1024").unwrap(), 1024);
    }

    #[test]
    fn unknown_option_rejected() {
        let a = args(&["--qsp", "5"]);
        assert!(a.check_known(&["qps"]).is_err());
        let b = args(&["--qps", "5"]);
        assert!(b.check_known(&["qps"]).is_ok());
    }

    #[test]
    fn negative_number_as_value() {
        let a = args(&["--offset", "-5.5"]);
        assert_eq!(a.f64_or("offset", 0.0).unwrap(), -5.5);
    }
}
