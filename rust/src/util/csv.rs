//! CSV reader/writer for load profiles, signal traces, and experiment
//! result tables (the Vessim-side interchange format in the paper's
//! pipeline is CSV).
//!
//! Handles quoting (RFC 4180), embedded commas/newlines, and typed
//! column access. No external crates.

use anyhow::{bail, Context, Result};
use std::fmt::Write as _;
use std::path::Path;

/// A parsed CSV table: header + rows of strings.
#[derive(Debug, Clone)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Convenience: push a row of display-able values.
    pub fn push<T: std::fmt::Display>(&mut self, cells: &[T]) {
        self.push_row(cells.iter().map(|c| c.to_string()).collect());
    }

    pub fn col_index(&self, name: &str) -> Result<usize> {
        self.header
            .iter()
            .position(|h| h == name)
            .with_context(|| format!("no column '{name}'"))
    }

    /// Typed numeric column.
    pub fn f64_col(&self, name: &str) -> Result<Vec<f64>> {
        let i = self.col_index(name)?;
        self.rows
            .iter()
            .map(|r| {
                r[i].parse::<f64>()
                    .with_context(|| format!("bad f64 '{}' in column {name}", r[i]))
            })
            .collect()
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        write_record(&mut out, &self.header);
        for row in &self.rows {
            write_record(&mut out, row);
        }
        out
    }

    /// Stream the table through one buffered writer — large tables
    /// (per-stage logs, minute-resolution profiles) never build a
    /// second whole-file `String` on top of their rows.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        use std::io::Write as _;
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let write_all = || -> std::io::Result<()> {
            let file = std::fs::File::create(path)?;
            let mut w = std::io::BufWriter::with_capacity(1 << 16, file);
            let mut line = String::new();
            write_record(&mut line, &self.header);
            w.write_all(line.as_bytes())?;
            for row in &self.rows {
                line.clear();
                write_record(&mut line, row);
                w.write_all(line.as_bytes())?;
            }
            w.flush()
        };
        write_all().with_context(|| format!("writing {path:?}"))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Table> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        parse(&text)
    }

    /// Render as a GitHub-markdown table (for reports).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "| {} |", self.header.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            let _ = writeln!(out, "| {} |", row.join(" | "));
        }
        out
    }
}

fn needs_quoting(s: &str) -> bool {
    s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r')
}

fn write_record(out: &mut String, cells: &[String]) {
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if needs_quoting(c) {
            out.push('"');
            out.push_str(&c.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(c);
        }
    }
    out.push('\n');
}

/// Parse a CSV document (first record is the header).
pub fn parse(text: &str) -> Result<Table> {
    let mut records = Vec::new();
    let mut cur: Vec<String> = Vec::new();
    let mut field = String::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;

    loop {
        match chars.next() {
            None => {
                if in_quotes {
                    bail!("unterminated quoted field");
                }
                if !field.is_empty() || !cur.is_empty() {
                    cur.push(std::mem::take(&mut field));
                    records.push(std::mem::take(&mut cur));
                }
                break;
            }
            Some('"') if in_quotes => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    field.push('"');
                } else {
                    in_quotes = false;
                }
            }
            Some('"') if field.is_empty() => in_quotes = true,
            Some(',') if !in_quotes => cur.push(std::mem::take(&mut field)),
            Some('\r') if !in_quotes => {} // swallow CR of CRLF
            Some('\n') if !in_quotes => {
                cur.push(std::mem::take(&mut field));
                records.push(std::mem::take(&mut cur));
            }
            Some(c) => field.push(c),
        }
    }

    if records.is_empty() {
        bail!("empty csv");
    }
    let header = records.remove(0);
    let width = header.len();
    for (i, r) in records.iter().enumerate() {
        if r.len() != width {
            bail!("row {i} has {} cells, header has {width}", r.len());
        }
    }
    Ok(Table {
        header,
        rows: records,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let mut t = Table::new(&["a", "b"]);
        t.push(&[1.5, 2.0]);
        t.push(&[3.0, 4.25]);
        let back = parse(&t.to_csv()).unwrap();
        assert_eq!(back.header, vec!["a", "b"]);
        assert_eq!(back.f64_col("b").unwrap(), vec![2.0, 4.25]);
    }

    #[test]
    fn quoting_roundtrip() {
        let mut t = Table::new(&["name", "note"]);
        t.push_row(vec!["x,y".into(), "he said \"hi\"\nnext".into()]);
        let back = parse(&t.to_csv()).unwrap();
        assert_eq!(back.rows[0][0], "x,y");
        assert_eq!(back.rows[0][1], "he said \"hi\"\nnext");
    }

    #[test]
    fn crlf_handled() {
        let t = parse("a,b\r\n1,2\r\n").unwrap();
        assert_eq!(t.rows, vec![vec!["1".to_string(), "2".to_string()]]);
    }

    #[test]
    fn ragged_rows_rejected() {
        assert!(parse("a,b\n1\n").is_err());
    }

    #[test]
    fn missing_column_errors() {
        let t = parse("a,b\n1,2\n").unwrap();
        assert!(t.f64_col("zzz").is_err());
    }

    #[test]
    fn markdown_render() {
        let mut t = Table::new(&["x"]);
        t.push(&[1u64]);
        let md = t.to_markdown();
        assert!(md.contains("| x |"));
        assert!(md.contains("| 1 |"));
    }
}
