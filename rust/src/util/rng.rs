//! Deterministic PRNG + the distributions the paper's workloads need.
//!
//! `rand`/`rand_distr` are not available offline, so this implements
//! xoshiro256++ (seeded via splitmix64) plus samplers for the
//! distributions Vidur's workload generators use: uniform, exponential
//! (Poisson arrivals), Poisson counts, bounded Zipf (request lengths,
//! paper: θ=0.6 over 1K–4K), normal (Box–Muller), gamma
//! (Marsaglia–Tsang), and log-normal.
//!
//! Everything is deterministic given a seed, which the simulator relies
//! on for reproducible experiments.

/// Derive the RNG seed of sweep case `index` from an experiment's base
/// seed: a splitmix64 finalization over both, so every case's stream
/// is (a) independent of execution order and of every other case —
/// parallel workers never touch shared sequential RNG state — and
/// (b) stable across `--jobs` settings, which is what makes `--jobs 1`
/// and `--jobs 8` sweeps byte-identical.
pub fn case_seed(base: u64, index: u64) -> u64 {
    let mut s = base
        ^ 0xA076_1D64_78BD_642F
        ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s)
}

/// xoshiro256++ PRNG (Blackman & Vigna). Fast, 256-bit state, passes
/// BigCrush; more than adequate for simulation workloads.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Seed the generator; any u64 seed is valid (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for parallel replicas/sweeps).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] (inclusive).
    pub fn int_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        let span = hi - lo + 1;
        // Lemire rejection-free-ish reduction; bias negligible for sim use
        // but use rejection for exactness.
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + v % span;
            }
        }
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Inter-arrival
    /// times of a Poisson process — the paper's arrival model.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        // Avoid ln(0).
        let u = 1.0 - self.f64();
        -u.ln() / lambda
    }

    /// Poisson count with mean `lambda` (Knuth for small, PTRS-style
    /// normal approximation fallback for large lambda).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // Normal approximation with continuity correction; fine for
            // the simulator's burst-count use.
            let n = self.normal(lambda, lambda.sqrt());
            n.max(0.0).round() as u64
        }
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn std_normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.std_normal()
    }

    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Gamma(shape k, scale θ) via Marsaglia–Tsang (k >= 1 squeeze;
    /// boost for k < 1).
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        debug_assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            let u = self.f64().max(f64::MIN_POSITIVE);
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.std_normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v * scale;
            }
        }
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.int_range(0, i as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.int_range(0, xs.len() as u64 - 1) as usize]
    }
}

/// Bounded Zipf sampler over `{lo, .., hi}` with exponent `theta`
/// (paper: request lengths Zipf(θ=0.6) over 1K–4K tokens).
///
/// Uses an inverted-CDF table; O(log n) per sample, exact.
#[derive(Debug, Clone)]
pub struct Zipf {
    lo: u64,
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(lo: u64, hi: u64, theta: f64) -> Self {
        assert!(hi >= lo, "zipf range empty");
        let n = (hi - lo + 1) as usize;
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            // rank 1 == lo (shortest requests are the most common,
            // matching the power-law structure of language data).
            acc += 1.0 / ((k + 1) as f64).powf(theta);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { lo, cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let u = rng.f64();
        let idx = match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i + 1,
            Err(i) => i,
        };
        self.lo + idx.min(self.cdf.len() - 1) as u64
    }

    /// Analytic mean of the distribution (used by capacity planning).
    pub fn mean(&self) -> f64 {
        let mut m = 0.0;
        let mut prev = 0.0;
        for (k, c) in self.cdf.iter().enumerate() {
            m += (self.lo + k as u64) as f64 * (c - prev);
            prev = *c;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform(2.0, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn int_range_inclusive_bounds() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.int_range(5, 8);
            assert!((5..=8).contains(&v));
            seen_lo |= v == 5;
            seen_hi |= v == 8;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let lambda = 6.45; // the paper's default QPS
        let n = 200_000;
        let mean: f64 =
            (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.002, "mean {mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(13);
        for &lambda in &[0.5, 4.0, 20.0, 100.0] {
            let n = 50_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(10.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.05);
        assert!((var - 4.0).abs() < 0.1);
    }

    #[test]
    fn gamma_moments() {
        let mut r = Rng::new(19);
        let (k, th) = (3.0, 2.0);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gamma(k, th)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - k * th).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn gamma_shape_below_one() {
        let mut r = Rng::new(23);
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| r.gamma(0.5, 1.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.03, "mean {mean}");
    }

    #[test]
    fn zipf_bounds_and_skew() {
        let mut r = Rng::new(29);
        let z = Zipf::new(1024, 4096, 0.6);
        let mut counts_low = 0;
        let n = 50_000;
        for _ in 0..n {
            let v = z.sample(&mut r);
            assert!((1024..=4096).contains(&v));
            if v < 2048 {
                counts_low += 1;
            }
        }
        // Zipf(0.6) over this range is mildly skewed towards short.
        assert!(counts_low as f64 > 0.35 * n as f64);
    }

    #[test]
    fn zipf_empirical_mean_matches_analytic() {
        let mut r = Rng::new(31);
        let z = Zipf::new(128, 512, 1.1);
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| z.sample(&mut r) as f64).sum::<f64>() / n as f64;
        assert!((mean - z.mean()).abs() < 2.0, "emp {mean} vs {}", z.mean());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(37);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn case_seeds_distinct_and_stable() {
        let a = case_seed(0xE1, 0);
        let b = case_seed(0xE1, 1);
        let c = case_seed(0xE2, 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
        // Pure function of (base, index): stable across calls.
        assert_eq!(a, case_seed(0xE1, 0));
        // Neighbouring indices yield uncorrelated streams.
        let mut ra = Rng::new(a);
        let mut rb = Rng::new(b);
        let same = (0..64).filter(|_| ra.next_u64() == rb.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(41);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
