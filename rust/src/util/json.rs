//! Minimal JSON implementation (serde/serde_json are unavailable
//! offline): a `Value` tree, a recursive-descent parser, and a
//! serializer with stable key order.
//!
//! Used for config files, telemetry export, the artifact manifest, and
//! experiment result files. Supports the full JSON grammar except
//! `\u` surrogate pairs outside the BMP (not needed here).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are f64 (JSON's own model).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    /// Insert into an object, panicking on a non-object receiver —
    /// the builder-style API for values whose shape is statically
    /// known (`Value::obj()` literals). When the receiver came from
    /// [`parse`] — i.e. its shape is decided by whoever wrote the
    /// input — use [`Value::try_set`] instead: a malformed document
    /// must surface as an `Err`, never abort the process (the serve
    /// plane's request handlers depend on this).
    pub fn set(&mut self, key: &str, v: impl Into<Value>) -> &mut Self {
        if let Err(e) = self.try_set(key, v) {
            panic!("{e}");
        }
        self
    }

    /// Non-panicking [`Value::set`]: inserts into an object receiver,
    /// errors (naming the key and the actual variant) on anything
    /// else.
    pub fn try_set(&mut self, key: &str, v: impl Into<Value>) -> anyhow::Result<&mut Self> {
        if let Value::Obj(m) = self {
            m.insert(key.to_string(), v.into());
            Ok(self)
        } else {
            anyhow::bail!("set('{key}') on non-object json value ({})", self.kind())
        }
    }

    /// The JSON type of this value, for error messages.
    pub fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Num(_) => "number",
            Value::Str(_) => "string",
            Value::Arr(_) => "array",
            Value::Obj(_) => "object",
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `v.at(&["a", "b"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required numeric field — deserializers of versioned formats
    /// (the shard telemetry sidecar) use these so a missing key fails
    /// loudly with the key name instead of defaulting to zero.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("missing/non-numeric json field '{key}'"))
    }

    /// Required integer field (JSON numbers are f64; exact for < 2^53).
    pub fn req_u64(&self, key: &str) -> anyhow::Result<u64> {
        let x = self.req_f64(key)?;
        anyhow::ensure!(
            x >= 0.0 && x == x.trunc(),
            "json field '{key}' is not a non-negative integer: {x}"
        );
        Ok(x as u64)
    }

    /// Required string field.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing/non-string json field '{key}'"))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => write_num(out, *x),
            Value::Str(s) => write_str(out, s),
            Value::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    e.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, e)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    e.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        out.push_str("null"); // JSON has no NaN/Inf
    } else if x == x.trunc() && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Value {
    fn from(x: f64) -> Self {
        Value::Num(x)
    }
}
impl From<u64> for Value {
    fn from(x: u64) -> Self {
        Value::Num(x as f64)
    }
}
impl From<usize> for Value {
    fn from(x: usize) -> Self {
        Value::Num(x as f64)
    }
}
impl From<i64> for Value {
    fn from(x: i64) -> Self {
        Value::Num(x as f64)
    }
}
impl From<u32> for Value {
    fn from(x: u32) -> Self {
        Value::Num(x as f64)
    }
}
impl From<bool> for Value {
    fn from(x: bool) -> Self {
        Value::Bool(x)
    }
}
impl From<&str> for Value {
    fn from(x: &str) -> Self {
        Value::Str(x.to_string())
    }
}
impl From<String> for Value {
    fn from(x: String) -> Self {
        Value::Str(x)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(xs: Vec<T>) -> Self {
        Value::Arr(xs.into_iter().map(Into::into).collect())
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        b: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl fmt::Display) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|e| self.err(e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "42", "-3.5", "1e-3", "\"hi\""] {
            let v = parse(s).unwrap();
            let back = parse(&v.to_string()).unwrap();
            assert_eq!(v, back);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.at(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Value::Null));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A");
        // Round-trip through serializer.
        let rt = parse(&v.to_string()).unwrap();
        assert_eq!(rt, v);
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn builder_api() {
        let mut v = Value::obj();
        v.set("n", 3u64).set("s", "str").set("b", true);
        v.set("arr", vec![1.0, 2.0]);
        let s = v.to_string();
        let back = parse(&s).unwrap();
        assert_eq!(back.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(back.get("arr").unwrap().as_arr().unwrap().len(), 2);
    }

    /// The satellite bugfix pinned down: mutating a value whose shape
    /// came from the wire must be able to fail as a `Result`, not
    /// abort the process.
    #[test]
    fn try_set_rejects_every_non_object_receiver() {
        for (text, kind) in [
            ("null", "null"),
            ("true", "bool"),
            ("3.5", "number"),
            ("\"s\"", "string"),
            ("[1,2]", "array"),
        ] {
            let mut v = parse(text).unwrap();
            let err = v.try_set("k", 1u64).err().expect(kind).to_string();
            assert!(err.contains("'k'") && err.contains(kind), "{err}");
            assert_eq!(v, parse(text).unwrap(), "receiver must be untouched");
        }
        // Object receivers succeed and chain like set().
        let mut v = parse("{}").unwrap();
        v.try_set("a", 1u64).unwrap().try_set("b", "x").unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.kind(), "object");
    }

    #[test]
    #[should_panic(expected = "non-object json value")]
    fn set_still_panics_on_non_object() {
        Value::Null.set("k", 1u64);
    }

    #[test]
    fn pretty_is_parseable() {
        let mut v = Value::obj();
        v.set("x", vec![1u64, 2, 3]);
        assert_eq!(parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Value::Num(42.0).to_string(), "42");
        assert_eq!(Value::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Value::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn required_accessors_fail_loudly() {
        let v = parse(r#"{"n": 3, "x": 0.5, "s": "hi"}"#).unwrap();
        assert_eq!(v.req_u64("n").unwrap(), 3);
        assert_eq!(v.req_f64("x").unwrap(), 0.5);
        assert_eq!(v.req_str("s").unwrap(), "hi");
        assert!(v.req_u64("x").is_err()); // not an integer
        assert!(v.req_f64("missing").is_err());
        assert!(v.req_str("n").is_err());
    }

    #[test]
    fn f64_roundtrips_exactly_through_serializer() {
        // The shard-telemetry sidecar relies on this: Rust's `{}`
        // float formatting is shortest-roundtrip, so JSON-serialized
        // accumulators reload bit-identical.
        for x in [0.1, 1.0 / 3.0, 6.45e-3, 1.234567890123456e300] {
            let s = Value::Num(x).to_string();
            assert_eq!(parse(&s).unwrap().as_f64().unwrap(), x, "{s}");
        }
    }
}
