//! Build identity, embedded once and surfaced everywhere that answers
//! "what exactly is running?": `repro --version` and the serve plane's
//! `GET /healthz` (DESIGN.md §11).

/// The crate version from Cargo.toml.
pub const CRATE_VERSION: &str = env!("CARGO_PKG_VERSION");

/// The `git describe --always --dirty --tags` string captured at build
/// time by `build.rs`, when the build ran inside a git checkout with a
/// git binary available; `None` otherwise (release tarballs, sandboxed
/// builds).
pub fn git_describe() -> Option<&'static str> {
    option_env!("REPRO_GIT_DESCRIBE")
}

/// Human-facing one-liner: `0.1.0 (1a2b3c4)` with a checkout, `0.1.0`
/// without.
pub fn version_string() -> String {
    match git_describe() {
        Some(g) => format!("{CRATE_VERSION} ({g})"),
        None => CRATE_VERSION.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn version_string_always_carries_the_crate_version() {
        assert!(!CRATE_VERSION.is_empty());
        let s = version_string();
        assert!(s.starts_with(CRATE_VERSION), "{s}");
        // With a describe string it must appear too.
        if let Some(g) = git_describe() {
            assert!(!g.is_empty());
            assert!(s.contains(g), "{s}");
        }
    }
}
