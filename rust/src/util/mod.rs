//! Foundation substrates built from scratch (no external crates
//! available offline beyond the `xla` closure): JSON, PRNG +
//! distributions, statistics, time series, CSV, CLI parsing, a
//! micro-benchmark harness and a property-testing driver.

pub mod json;
pub mod rng;
pub mod stats;
pub mod timeseries;
pub mod csv;
pub mod cli;
pub mod bench;
pub mod proptest;
pub mod version;
