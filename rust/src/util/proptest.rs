//! Minimal property-testing driver (proptest is unavailable offline).
//!
//! `check(cases, gen, prop)` runs `prop` over `cases` randomly generated
//! inputs; on failure it performs greedy shrinking via the generator's
//! `shrink` hook and panics with the minimal counterexample. Generators
//! are plain closures over the crate's own `Rng`.

use crate::util::rng::Rng;

/// A reproducible input generator. `gen` draws a value; `shrink`
/// proposes smaller candidates (may be empty).
pub struct Gen<T> {
    pub gen: Box<dyn Fn(&mut Rng) -> T>,
    pub shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + std::fmt::Debug + 'static> Gen<T> {
    pub fn new(gen: impl Fn(&mut Rng) -> T + 'static) -> Self {
        Gen {
            gen: Box::new(gen),
            shrink: Box::new(|_| Vec::new()),
        }
    }

    pub fn with_shrink(mut self, s: impl Fn(&T) -> Vec<T> + 'static) -> Self {
        self.shrink = Box::new(s);
        self
    }
}

/// Run a property over `cases` random inputs. The seed comes from
/// REPRO_PROPTEST_SEED when set (reproducing failures), else a fixed
/// default so CI is deterministic.
pub fn check<T: Clone + std::fmt::Debug + 'static>(
    cases: usize,
    g: Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let seed = std::env::var("REPRO_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = (g.gen)(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 200 {
                improved = false;
                rounds += 1;
                for cand in (g.shrink)(&best) {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property failed (case {case}, seed {seed}):\n  input: {best:?}\n  error: {best_msg}\n  (set REPRO_PROPTEST_SEED={seed} to reproduce)"
            );
        }
    }
}

/// Generator helpers for common shapes.
pub mod gens {
    use super::*;

    pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
        Gen::new(move |r| r.uniform(lo, hi)).with_shrink(move |&x| {
            let mut v = Vec::new();
            if x != lo {
                v.push(lo);
                v.push(lo + (x - lo) / 2.0);
            }
            v
        })
    }

    pub fn u64_in(lo: u64, hi: u64) -> Gen<u64> {
        Gen::new(move |r| r.int_range(lo, hi)).with_shrink(move |&x| {
            // Candidates spread over [lo, x): lets the greedy loop close
            // in on a failure boundary quickly.
            let mut v: Vec<u64> = (0..16u64).map(|k| lo + (x - lo) * k / 16).collect();
            if x > lo {
                v.push(x - 1);
            }
            v.sort();
            v.dedup();
            v.retain(|&c| c < x);
            v
        })
    }

    /// Vector of f64 with shrinking by halving length.
    pub fn vec_f64(max_len: usize, lo: f64, hi: f64) -> Gen<Vec<f64>> {
        Gen::new(move |r| {
            let n = r.int_range(1, max_len as u64) as usize;
            (0..n).map(|_| r.uniform(lo, hi)).collect()
        })
        .with_shrink(|v: &Vec<f64>| {
            if v.len() <= 1 {
                return Vec::new();
            }
            vec![v[..v.len() / 2].to_vec(), v[v.len() / 2..].to_vec()]
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        check(100, gens::f64_in(0.0, 1.0), |&x| {
            if (0.0..=1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_input() {
        check(100, gens::u64_in(0, 1000), |&x| {
            if x < 500 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn shrinking_finds_smaller_counterexample() {
        // Catch the panic and check it shrank towards the boundary.
        let r = std::panic::catch_unwind(|| {
            check(200, gens::u64_in(0, 10_000), |&x| {
                if x < 5000 {
                    Ok(())
                } else {
                    Err("boom".into())
                }
            });
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        // The shrinker closes in on the failure boundary: the reported
        // input must be in [5000, 5200).
        let shrunk: u64 = msg
            .split("input: ")
            .nth(1)
            .and_then(|s| s.split_whitespace().next())
            .and_then(|s| s.parse().ok())
            .expect("no input in panic message");
        assert!(
            (5000..5200).contains(&shrunk),
            "unexpected shrink result: {shrunk}"
        );
    }

    #[test]
    fn vec_generator_respects_bounds() {
        check(50, gens::vec_f64(32, -1.0, 1.0), |v| {
            if v.is_empty() || v.len() > 32 {
                return Err("bad length".into());
            }
            if v.iter().any(|x| !(-1.0..=1.0).contains(x)) {
                return Err("out of range".into());
            }
            Ok(())
        });
    }
}
