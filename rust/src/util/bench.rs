//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Each `[[bench]]` target is a `harness = false` binary that builds a
//! `Bench` suite, registers cases, and calls `run()`. The harness does
//! warmup, adaptively picks an iteration count to hit a target wall
//! time, and reports mean / p50 / p99 per case as a markdown table —
//! plus an optional "paper value" column so every bench doubles as a
//! table/figure regenerator.

use crate::util::stats::{percentile, Summary};
use std::time::{Duration, Instant};

pub struct Bench {
    name: String,
    target_time: Duration,
    warmup: Duration,
    results: Vec<CaseResult>,
}

#[derive(Debug, Clone)]
pub struct CaseResult {
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub std_s: f64,
    /// Free-form metric the case reports (e.g. "kWh=0.49"): benches
    /// regenerate paper numbers, not just latencies.
    pub metric: String,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        // Fast mode for CI: REPRO_BENCH_FAST=1 shrinks budgets ~10x.
        let fast = std::env::var("REPRO_BENCH_FAST").is_ok();
        Bench {
            name: name.to_string(),
            target_time: if fast {
                Duration::from_millis(300)
            } else {
                Duration::from_secs(2)
            },
            warmup: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(300)
            },
            results: Vec::new(),
        }
    }

    pub fn with_target_time(mut self, d: Duration) -> Self {
        self.target_time = d;
        self
    }

    /// Benchmark `f`; its return value is folded into a metric string
    /// via `metric_of` on the final iteration.
    pub fn case_with_metric<T>(
        &mut self,
        name: &str,
        mut f: impl FnMut() -> T,
        metric_of: impl Fn(&T) -> String,
    ) {
        // Warmup + calibration.
        let warm_start = Instant::now();
        let mut calib_iters = 0u64;
        let mut last = f();
        calib_iters += 1;
        while warm_start.elapsed() < self.warmup {
            last = f();
            calib_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / calib_iters as f64;
        let iters = ((self.target_time.as_secs_f64() / per_iter.max(1e-9)) as u64)
            .clamp(3, 1_000_000);

        let mut times = Vec::with_capacity(iters as usize);
        let mut summary = Summary::new();
        for _ in 0..iters {
            let t0 = Instant::now();
            last = f();
            let dt = t0.elapsed().as_secs_f64();
            times.push(dt);
            summary.add(dt);
        }
        let metric = metric_of(&last);
        self.results.push(CaseResult {
            name: name.to_string(),
            iters,
            mean_s: summary.mean(),
            p50_s: percentile(&times, 50.0),
            p99_s: percentile(&times, 99.0),
            std_s: summary.std(),
            metric,
        });
        // Print progress as we go (benches can be long).
        let r = self.results.last().unwrap();
        eprintln!(
            "  {:<40} {:>10} iters  mean {:>12}  {}",
            r.name,
            r.iters,
            fmt_time(r.mean_s),
            r.metric
        );
    }

    pub fn case<T>(&mut self, name: &str, f: impl FnMut() -> T) {
        self.case_with_metric(name, f, |_| String::new());
    }

    /// One-shot measurement (for long end-to-end cases where iterating
    /// is impractical): runs once, records the time.
    pub fn once<T>(
        &mut self,
        name: &str,
        mut f: impl FnMut() -> T,
        metric_of: impl Fn(&T) -> String,
    ) {
        let t0 = Instant::now();
        let v = f();
        let dt = t0.elapsed().as_secs_f64();
        self.results.push(CaseResult {
            name: name.to_string(),
            iters: 1,
            mean_s: dt,
            p50_s: dt,
            p99_s: dt,
            std_s: 0.0,
            metric: metric_of(&v),
        });
        let r = self.results.last().unwrap();
        eprintln!(
            "  {:<40} {:>10} iters  mean {:>12}  {}",
            r.name, 1, fmt_time(dt), r.metric
        );
    }

    /// Print the final report table; returns results for programmatic use.
    pub fn run(self) -> Vec<CaseResult> {
        println!("\n## bench: {}\n", self.name);
        println!(
            "| case | iters | mean | p50 | p99 | std | metric |"
        );
        println!("|---|---|---|---|---|---|---|");
        for r in &self.results {
            println!(
                "| {} | {} | {} | {} | {} | {} | {} |",
                r.name,
                r.iters,
                fmt_time(r.mean_s),
                fmt_time(r.p50_s),
                fmt_time(r.p99_s),
                fmt_time(r.std_s),
                r.metric
            );
        }
        println!();
        self.results
    }
}

pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Prevent the optimizer from eliding a value (std::hint::black_box
/// stand-in that also works on references).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_cases() {
        std::env::set_var("REPRO_BENCH_FAST", "1");
        let mut b = Bench::new("selftest").with_target_time(Duration::from_millis(30));
        b.case("noop", || black_box(1 + 1));
        b.case_with_metric("metric", || 42u64, |v| format!("v={v}"));
        let rs = b.run();
        assert_eq!(rs.len(), 2);
        assert!(rs[0].iters >= 3);
        assert_eq!(rs[1].metric, "v=42");
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
