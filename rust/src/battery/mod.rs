//! Battery model (Vessim's `ClcBattery` equivalent): capacity, SoC
//! window, charge/discharge power limits, round-trip efficiency, and
//! cycle counting — the storage element of the co-simulated microgrid.

use crate::config::simconfig::CosimConfig;

/// Rate- and SoC-limited battery.
#[derive(Debug, Clone)]
pub struct Battery {
    pub capacity_wh: f64,
    pub soc: f64,
    pub soc_min: f64,
    pub soc_max: f64,
    pub max_charge_w: f64,
    pub max_discharge_w: f64,
    pub eff_charge: f64,
    pub eff_discharge: f64,
    /// Cumulative discharged energy, Wh (for full-cycle counting).
    pub discharged_wh: f64,
    pub charged_wh: f64,
}

impl Battery {
    pub fn from_config(c: &CosimConfig) -> Self {
        Battery {
            capacity_wh: c.battery_wh,
            soc: c.soc_init,
            soc_min: c.soc_min,
            soc_max: c.soc_max,
            max_charge_w: c.max_charge_w,
            max_discharge_w: c.max_discharge_w,
            eff_charge: c.charge_eff,
            eff_discharge: c.discharge_eff,
            discharged_wh: 0.0,
            charged_wh: 0.0,
        }
    }

    /// Offer `power_w` of surplus for `dt_s`; returns the power
    /// actually absorbed (grid export takes the rest).
    pub fn charge(&mut self, power_w: f64, dt_s: f64) -> f64 {
        let dt_h = dt_s / 3600.0;
        let room_wh = (self.soc_max - self.soc) * self.capacity_wh;
        let mut p = power_w.min(self.max_charge_w);
        p = p.min(room_wh / (dt_h * self.eff_charge));
        p = p.max(0.0);
        self.soc += p * self.eff_charge * dt_h / self.capacity_wh;
        self.soc = self.soc.clamp(0.0, 1.0);
        self.charged_wh += p * dt_h;
        p
    }

    /// Request `power_w` of deficit coverage for `dt_s`; returns the
    /// power actually delivered (grid import covers the rest).
    pub fn discharge(&mut self, power_w: f64, dt_s: f64) -> f64 {
        let dt_h = dt_s / 3600.0;
        let avail_wh = (self.soc - self.soc_min) * self.capacity_wh;
        let mut p = power_w.min(self.max_discharge_w);
        p = p.min(avail_wh * self.eff_discharge / dt_h);
        p = p.max(0.0);
        self.soc -= p / self.eff_discharge * dt_h / self.capacity_wh;
        self.soc = self.soc.clamp(0.0, 1.0);
        self.discharged_wh += p * dt_h;
        p
    }

    /// Equivalent full cycles so far (discharged energy / capacity).
    pub fn full_cycles(&self) -> f64 {
        self.discharged_wh / self.capacity_wh
    }

    /// The bp[8] parameter vector for the AOT cosim kernel (layout:
    /// python/compile/kernels/ref.py).
    pub fn param_vec(&self, dt_s: f64) -> [f32; 8] {
        [
            self.capacity_wh as f32,
            self.soc_min as f32,
            self.soc_max as f32,
            self.max_charge_w as f32,
            self.max_discharge_w as f32,
            self.eff_charge as f32,
            self.eff_discharge as f32,
            dt_s as f32,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batt() -> Battery {
        Battery::from_config(&CosimConfig::default())
    }

    #[test]
    fn paper_defaults() {
        let b = batt();
        assert_eq!(b.capacity_wh, 100.0);
        assert_eq!((b.soc_min, b.soc_max), (0.2, 0.8));
        assert_eq!(b.soc, 0.5);
    }

    #[test]
    fn charge_respects_soc_max() {
        let mut b = batt();
        // Offer far more than fits: 0.5 -> 0.8 = 30 Wh room.
        let mut absorbed_wh = 0.0;
        for _ in 0..120 {
            absorbed_wh += b.charge(1000.0, 60.0) / 60.0;
        }
        assert!((b.soc - 0.8).abs() < 1e-6, "soc {}", b.soc);
        // Energy absorbed ≈ room / eff.
        assert!((absorbed_wh - 30.0 / 0.95).abs() < 0.2, "{absorbed_wh}");
    }

    #[test]
    fn discharge_respects_soc_min() {
        let mut b = batt();
        for _ in 0..120 {
            b.discharge(1000.0, 60.0);
        }
        assert!((b.soc - 0.2).abs() < 1e-6, "soc {}", b.soc);
        assert_eq!(b.discharge(100.0, 60.0), 0.0); // empty
    }

    #[test]
    fn rate_limits_enforced() {
        let mut b = batt();
        assert_eq!(b.charge(1000.0, 1.0), 100.0); // max_charge_w
        assert_eq!(b.discharge(1000.0, 1.0), 100.0); // max_discharge_w
    }

    #[test]
    fn round_trip_loses_energy() {
        // Start empty: everything discharged later must have come from
        // the charge, exposing the round-trip efficiency.
        let mut b = batt();
        b.soc = b.soc_min;
        let in_w = b.charge(20.0, 3600.0); // 20 Wh in
        assert!((in_w - 20.0).abs() < 1e-9);
        let out_w = b.discharge(1000.0, 3600.0);
        let rt = out_w / in_w;
        assert!(
            (rt - 0.95 * 0.95).abs() < 0.01,
            "round-trip efficiency {rt}"
        );
    }

    #[test]
    fn cycle_counting() {
        let mut b = batt();
        // From SoC 0.5 with floor 0.2: 30 Wh stored ⇒ 28.5 Wh at the
        // terminals (discharge efficiency 0.95).
        b.discharge(1000.0, 3600.0);
        assert!((b.full_cycles() - 0.285).abs() < 1e-6, "{}", b.full_cycles());
    }

    #[test]
    fn zero_dt_safe() {
        let mut b = batt();
        let soc0 = b.soc;
        b.charge(100.0, 0.0);
        b.discharge(100.0, 0.0);
        assert!(b.soc.is_finite());
        assert_eq!(b.soc, soc0); // no time elapsed, no energy moved
    }

    /// Property: any random interleaving of charge/discharge calls
    /// (random powers and step sizes)
    /// * conserves energy up to round-trip efficiency — terminals-out
    ///   never exceeds (initial stored + terminals-in × η_c) × η_d;
    /// * keeps the SoC ledger exact: soc movement equals
    ///   charged × η_c − discharged / η_d;
    /// * never leaves the [soc_min, soc_max] window;
    /// * keeps `full_cycles()` monotone nondecreasing.
    #[test]
    fn random_interleavings_conserve_energy_and_soc_window() {
        use crate::util::proptest::{check, gens};
        use crate::util::rng::Rng;
        check(60, gens::u64_in(0, u64::MAX / 2), |&seed| {
            let mut rng = Rng::new(seed);
            let mut b = batt();
            let stored0_wh = (b.soc - b.soc_min) * b.capacity_wh;
            let (mut in_wh, mut out_wh) = (0.0f64, 0.0f64);
            let mut last_cycles = b.full_cycles();
            let eps = 1e-6;
            for step in 0..200 {
                let power = rng.uniform(0.0, 300.0);
                let dt = rng.uniform(0.0, 900.0);
                if rng.f64() < 0.5 {
                    in_wh += b.charge(power, dt) * dt / 3600.0;
                } else {
                    out_wh += b.discharge(power, dt) * dt / 3600.0;
                }
                if !(b.soc_min - eps..=b.soc_max + eps).contains(&b.soc) {
                    return Err(format!(
                        "seed {seed} step {step}: soc {} left [{}, {}]",
                        b.soc, b.soc_min, b.soc_max
                    ));
                }
                let cycles = b.full_cycles();
                if cycles < last_cycles - eps {
                    return Err(format!(
                        "seed {seed} step {step}: full_cycles went {last_cycles} -> {cycles}"
                    ));
                }
                last_cycles = cycles;
                // Round-trip conservation: everything at the output
                // terminals came through both efficiency losses.
                let max_out = (stored0_wh + in_wh * b.eff_charge) * b.eff_discharge;
                if out_wh > max_out + eps {
                    return Err(format!(
                        "seed {seed} step {step}: out {out_wh} Wh > ({stored0_wh} + \
                         {in_wh}·ηc)·ηd = {max_out} Wh"
                    ));
                }
                // Exact ledger: SoC movement == net terminal energy
                // through the efficiencies.
                let expect_soc = 0.5
                    + (b.charged_wh * b.eff_charge - b.discharged_wh / b.eff_discharge)
                        / b.capacity_wh;
                if (b.soc - expect_soc).abs() > 1e-6 {
                    return Err(format!(
                        "seed {seed} step {step}: soc ledger drift {} vs {}",
                        b.soc, expect_soc
                    ));
                }
            }
            Ok(())
        });
    }
}
