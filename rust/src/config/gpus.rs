//! GPU spec registry with the paper's calibrated power points
//! (§3.1 "Power Model Calibration"): A100 100/400 W, H100 60/700 W,
//! A40 30/300 W, plus compute/memory/interconnect characteristics and
//! the Eq. 1 power-law parameters (§4.1: mfu_sat = 0.45, γ = 0.7).

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InterconnectKind {
    /// NVLink pairwise (the paper's Table 1b topology).
    NvLink,
    /// PCIe fallback (A40).
    Pcie,
}

impl InterconnectKind {
    /// Effective per-direction link bandwidth, bytes/s.
    pub fn bandwidth(&self) -> f64 {
        match self {
            // NVLink 3 (A100 generation): 300 GB/s pairwise effective.
            InterconnectKind::NvLink => 250e9,
            // PCIe 4.0 x16 ~ 25 GB/s effective.
            InterconnectKind::Pcie => 20e9,
        }
    }

    /// Per-collective latency, seconds.
    pub fn latency(&self) -> f64 {
        match self {
            InterconnectKind::NvLink => 5e-6,
            InterconnectKind::Pcie => 15e-6,
        }
    }
}

/// One GPU SKU: compute, memory, and the calibrated power model.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    pub display: &'static str,
    /// Peak dense BF16/FP16 FLOPs/s (no sparsity).
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// VRAM, bytes.
    pub vram_bytes: f64,
    /// Idle power draw, W (paper §3.1).
    pub p_idle: f64,
    /// Max instantaneous power under saturation, W (paper §3.1).
    pub p_max_inst: f64,
    /// Eq. 1 saturation threshold (paper §4.1: 0.45 for A100).
    pub mfu_sat: f64,
    /// Eq. 1 exponent (paper §4.1: 0.7).
    pub gamma: f64,
    /// Embodied-carbon rate φ_manuf, gCO₂ per GPU-hour (Eq. 4);
    /// derived from ~150 kgCO₂e manufacturing over a 5-year life.
    pub phi_manuf: f64,
    pub interconnect: InterconnectKind,
}

impl GpuSpec {
    /// Eq. 1 — the paper's GPU power model.
    pub fn power(&self, mfu: f64) -> f64 {
        let x = (mfu / self.mfu_sat).clamp(0.0, 1.0);
        self.p_idle + (self.p_max_inst - self.p_idle) * x.powf(self.gamma)
    }
}

/// Calibrated SKUs (paper §3.1). phi_manuf: 150 kg / (5y × 8760 h) ≈ 3.42 g/h.
pub const GPUS: &[GpuSpec] = &[
    GpuSpec {
        name: "a100-80g",
        display: "NVIDIA A100 (80GB SXM4)",
        peak_flops: 312e12,
        hbm_bw: 2.039e12,
        vram_bytes: 80e9,
        p_idle: 100.0,
        p_max_inst: 400.0,
        mfu_sat: 0.45,
        gamma: 0.7,
        phi_manuf: 3.42,
        interconnect: InterconnectKind::NvLink,
    },
    GpuSpec {
        name: "h100",
        display: "NVIDIA H100 (SXM5)",
        peak_flops: 989e12,
        hbm_bw: 3.35e12,
        vram_bytes: 80e9,
        p_idle: 60.0,
        p_max_inst: 700.0,
        mfu_sat: 0.45,
        gamma: 0.7,
        phi_manuf: 3.42,
        interconnect: InterconnectKind::NvLink,
    },
    GpuSpec {
        name: "a40",
        display: "NVIDIA A40 (PCIe)",
        peak_flops: 149.7e12,
        hbm_bw: 0.696e12,
        vram_bytes: 48e9,
        p_idle: 30.0,
        p_max_inst: 300.0,
        mfu_sat: 0.45,
        gamma: 0.7,
        phi_manuf: 2.5,
        interconnect: InterconnectKind::Pcie,
    },
];

pub fn gpu(name: &str) -> Result<&'static GpuSpec> {
    match GPUS.iter().find(|g| g.name == name) {
        Some(g) => Ok(g),
        None => bail!(
            "unknown gpu '{name}'; known: {}",
            GPUS.iter().map(|g| g.name).collect::<Vec<_>>().join(", ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_calibration_points() {
        let a100 = gpu("a100-80g").unwrap();
        assert_eq!((a100.p_idle, a100.p_max_inst), (100.0, 400.0));
        let h100 = gpu("h100").unwrap();
        assert_eq!((h100.p_idle, h100.p_max_inst), (60.0, 700.0));
        let a40 = gpu("a40").unwrap();
        assert_eq!((a40.p_idle, a40.p_max_inst), (30.0, 300.0));
    }

    #[test]
    fn power_at_zero_is_idle() {
        for g in GPUS {
            assert_eq!(g.power(0.0), g.p_idle);
        }
    }

    #[test]
    fn power_saturates_at_threshold() {
        let g = gpu("a100-80g").unwrap();
        assert!((g.power(0.45) - 400.0).abs() < 1e-9);
        assert!((g.power(0.9) - 400.0).abs() < 1e-9);
    }

    #[test]
    fn power_sublinear_below_saturation() {
        // γ<1: halfway MFU yields more than half of the dynamic range.
        let g = gpu("a100-80g").unwrap();
        let mid = g.power(0.225);
        let frac = (mid - 100.0) / 300.0;
        assert!(frac > 0.5, "power-law not sublinear: {frac}");
        // Monotone.
        let mut prev = 0.0;
        for i in 0..=100 {
            let p = g.power(i as f64 * 0.45 / 100.0);
            assert!(p >= prev);
            prev = p;
        }
    }

    #[test]
    fn paper_example_30pct_mfu_drop_small_power_drop() {
        // §2: "when MFU drops by 30%, power may decline by under 10%".
        let g = gpu("a100-80g").unwrap();
        let p_hi = g.power(0.45);
        let p_lo = g.power(0.45 * 0.7);
        let drop = (p_hi - p_lo) / p_hi;
        assert!(drop < 0.20, "drop {drop}"); // sublinear: far less than 30%
    }

    #[test]
    fn unknown_gpu_is_error() {
        assert!(gpu("tpu-v4").is_err());
    }
}
