//! Configuration layer: model architecture registry, GPU spec registry
//! (with the paper's calibrated power points), and the full simulation /
//! co-simulation configuration structures with JSON round-tripping.

pub mod models;
pub mod gpus;
pub mod simconfig;

pub use gpus::{GpuSpec, InterconnectKind};
pub use models::ModelSpec;
pub use simconfig::{CosimConfig, SimConfig, WorkloadKind};
