//! Full simulation / co-simulation configuration (the paper's Table 1),
//! with JSON round-tripping for config files and experiment records.

use crate::config::{gpus, models};
use crate::util::json::{self, Value};
use anyhow::{bail, Context, Result};

/// Request-length distribution (paper: Zipfian, reflecting the
/// power-law structure of language data).
#[derive(Debug, Clone, PartialEq)]
pub enum LengthDist {
    /// Bounded Zipf over total tokens (θ, min, max).
    Zipf { theta: f64, min: u64, max: u64 },
    /// All requests exactly `total` tokens.
    Fixed { total: u64 },
    /// Uniform over [min, max].
    Uniform { min: u64, max: u64 },
}

/// Arrival process.
#[derive(Debug, Clone, PartialEq)]
pub enum Arrival {
    /// Poisson arrivals at `qps` (the paper's default).
    Poisson { qps: f64 },
    /// Gamma-distributed inter-arrivals (burstier; cv > 1).
    Gamma { qps: f64, cv: f64 },
    /// All requests arrive at t=0 (offline / batch mode).
    Batch,
}

impl Arrival {
    pub fn qps(&self) -> f64 {
        match self {
            Arrival::Poisson { qps } | Arrival::Gamma { qps, .. } => *qps,
            Arrival::Batch => f64::INFINITY,
        }
    }
}

/// Replica-level scheduler policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// vLLM-style continuous batching with full prefill bursts (default).
    Vllm,
    /// Sarathi-style chunked prefill + piggybacked decode.
    Sarathi,
    /// Orca-style iteration-level scheduling without paged KV
    /// admission control (simplified baseline).
    Orca,
}

/// Cluster-level request router.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterKind {
    RoundRobin,
    /// Least outstanding requests.
    LeastOutstanding,
}

/// Which execution-time/power oracle backs the simulator hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CostModelKind {
    /// Pure-rust analytical roofline (fast cross-check).
    Native,
    /// AOT-compiled JAX/Pallas stage oracle via PJRT (default; the
    /// three-layer architecture's request-path artifact).
    Hlo,
    /// Interpolated cost surface (DESIGN.md §12): per-config tables
    /// sampled once from an inner oracle (HLO when artifacts are
    /// present, else native) and shared across sweep workers.
    Surface,
}

/// Where the request stream comes from (DESIGN.md §14): the synthetic
/// Poisson/Zipf generator, a recorded trace replayed off disk, one of
/// the built-in scenario generators, or a weighted mix of scenarios.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadKind {
    /// The paper's synthetic generator (`arrival` × `lengths`).
    Synthetic,
    /// Stream a recorded trace (CSV/JSONL; native or
    /// timestamp/prompt/output schema) without materializing it.
    Trace {
        path: String,
        /// Multiplier on arrival times (0.5 = twice the rate).
        time_scale: f64,
        /// Total passes over the trace (loop a short trace).
        repeat: u32,
    },
    /// Multi-turn conversations with shared-prefix accounting.
    Chat,
    /// RAG-style long-prefill / short-decode queries.
    Rag,
    /// Agentic tool-call loops (correlated arrival bursts).
    Agentic,
    /// Heavy-tailed multi-tenant mix with per-tenant profiles.
    Tenants,
    /// Weighted mix of named scenarios, e.g. `[("chat", 2.0), ("rag", 1.0)]`.
    Mix(Vec<(String, f64)>),
}

impl Default for WorkloadKind {
    fn default() -> Self {
        WorkloadKind::Synthetic
    }
}

/// Scenario names accepted inside `mix:` specs (everything except
/// trace/mix themselves, which don't nest).
pub const MIXABLE_WORKLOADS: &[&str] = &["synthetic", "chat", "rag", "agentic", "tenants"];

impl WorkloadKind {
    /// Parse the CLI/JSON spec form:
    /// `synthetic | chat | rag | agentic | tenants | trace:PATH |
    /// mix:NAME=WEIGHT,...`. Trace time-scale/repeat ride on separate
    /// knobs (`--trace-scale`/`--trace-repeat`).
    pub fn parse(s: &str) -> Result<WorkloadKind> {
        Ok(match s {
            "synthetic" => WorkloadKind::Synthetic,
            "chat" => WorkloadKind::Chat,
            "rag" => WorkloadKind::Rag,
            "agentic" => WorkloadKind::Agentic,
            "tenants" => WorkloadKind::Tenants,
            _ if s.starts_with("trace:") => WorkloadKind::Trace {
                path: s["trace:".len()..].to_string(),
                time_scale: 1.0,
                repeat: 1,
            },
            _ if s.starts_with("mix:") => {
                let mut parts = Vec::new();
                for entry in s["mix:".len()..].split(',') {
                    let entry = entry.trim();
                    if entry.is_empty() {
                        continue;
                    }
                    let (name, w) = match entry.split_once('=') {
                        Some((n, w)) => (
                            n.trim().to_string(),
                            w.trim()
                                .parse::<f64>()
                                .with_context(|| format!("bad mix weight in '{entry}'"))?,
                        ),
                        None => (entry.to_string(), 1.0),
                    };
                    parts.push((name, w));
                }
                WorkloadKind::Mix(parts)
            }
            k => bail!(
                "unknown workload '{k}' \
                 (synthetic | chat | rag | agentic | tenants | trace:PATH | mix:NAME=W,...)"
            ),
        })
    }

    /// Canonical spec string (inverse of [`WorkloadKind::parse`] up to
    /// trace time-scale/repeat, which serialize as separate fields).
    pub fn spec(&self) -> String {
        match self {
            WorkloadKind::Synthetic => "synthetic".into(),
            WorkloadKind::Trace { path, .. } => format!("trace:{path}"),
            WorkloadKind::Chat => "chat".into(),
            WorkloadKind::Rag => "rag".into(),
            WorkloadKind::Agentic => "agentic".into(),
            WorkloadKind::Tenants => "tenants".into(),
            WorkloadKind::Mix(parts) => {
                let body: Vec<String> =
                    parts.iter().map(|(n, w)| format!("{n}={w}")).collect();
                format!("mix:{}", body.join(","))
            }
        }
    }

    pub fn validate(&self) -> Result<()> {
        match self {
            WorkloadKind::Trace { path, time_scale, repeat } => {
                if path.is_empty() {
                    bail!("trace workload needs a path (trace:PATH)");
                }
                if !(time_scale.is_finite() && *time_scale > 0.0) {
                    bail!("trace time scale must be positive and finite, got {time_scale}");
                }
                if *repeat == 0 {
                    bail!("trace repeat must be >= 1");
                }
            }
            WorkloadKind::Mix(parts) => {
                if parts.is_empty() {
                    bail!("mix workload needs at least one component (mix:NAME=W,...)");
                }
                for (name, w) in parts {
                    if !MIXABLE_WORKLOADS.contains(&name.as_str()) {
                        bail!(
                            "mix component '{name}' is not mixable \
                             (allowed: {MIXABLE_WORKLOADS:?})"
                        );
                    }
                    if !(w.is_finite() && *w > 0.0) {
                        bail!("mix weight for '{name}' must be positive and finite, got {w}");
                    }
                }
            }
            _ => {}
        }
        Ok(())
    }
}

/// Execution-model calibration knobs (see DESIGN.md §5 — substitutes
/// Vidur's random-forest runtime predictor with a calibrated roofline).
#[derive(Debug, Clone, PartialEq)]
pub struct ExecParams {
    /// Achievable fraction of peak FLOPs (Trainy: LLM kernels plateau
    /// near 35–45% MFU; this is that ceiling).
    pub flops_eff: f64,
    /// Achievable fraction of HBM bandwidth.
    pub mem_eff: f64,
    /// Fixed per-stage overhead, seconds (scheduler + launch tax).
    pub t_overhead: f64,
    /// Per-layer kernel-launch overhead, seconds.
    pub layer_overhead: f64,
    /// Std-dev of the multiplicative log-normal noise applied to stage
    /// times, emulating Vidur's learned-predictor spread (k=10 forest).
    pub rf_noise_std: f64,
}

impl Default for ExecParams {
    fn default() -> Self {
        ExecParams {
            flops_eff: 0.46,
            mem_eff: 0.80,
            t_overhead: 5e-4,
            layer_overhead: 2.5e-5,
            rf_noise_std: 0.0,
        }
    }
}

/// The Vidur-side simulation configuration (Table 1, panel a).
#[derive(Debug, Clone, PartialEq)]
pub struct SimConfig {
    pub model: String,
    pub gpu: String,
    pub tp: u32,
    pub pp: u32,
    pub replicas: u32,
    pub scheduler: SchedulerKind,
    pub router: RouterKind,
    pub cost_model: CostModelKind,
    /// Max requests per running batch (paper: 128).
    pub batch_cap: usize,
    /// Max total tokens per request (paper: 4096).
    pub max_tokens: u64,
    pub num_requests: u64,
    pub arrival: Arrival,
    pub lengths: LengthDist,
    /// Request-stream source (DESIGN.md §14). `Synthetic` uses
    /// `arrival` × `lengths`; scenarios reuse `arrival.qps()` as their
    /// aggregate rate; traces ignore both.
    pub workload: WorkloadKind,
    /// Prefill:decode token ratio; when set, splits each sampled total
    /// length into prefill/decode by this ratio (Exp. 2 sweeps it).
    pub prefill_decode_ratio: Option<f64>,
    /// Sarathi chunk size (tokens per prefill chunk).
    pub chunk_size: u64,
    /// KV-cache block size in tokens (vLLM-style paging).
    pub kv_block_tokens: u64,
    /// Power-usage effectiveness of the site (paper: 1.2, CA).
    pub pue: f64,
    /// TTFT service-level objective, seconds (SLO-attainment metrics
    /// and the autoscaler's SLO guard measure against this).
    pub slo_ttft_s: f64,
    /// End-to-end latency SLO, seconds.
    pub slo_e2e_s: f64,
    pub exec: ExecParams,
    pub seed: u64,
}

impl Default for SimConfig {
    /// The paper's default Vidur configuration (Table 1, panel a).
    fn default() -> Self {
        SimConfig {
            model: "llama3-8b".into(),
            gpu: "a100-80g".into(),
            tp: 1,
            pp: 1,
            replicas: 1,
            scheduler: SchedulerKind::Vllm,
            router: RouterKind::RoundRobin,
            cost_model: CostModelKind::Hlo,
            batch_cap: 128,
            max_tokens: 4096,
            num_requests: 1024,
            arrival: Arrival::Poisson { qps: 6.45 },
            lengths: LengthDist::Zipf {
                theta: 0.6,
                min: 128,
                max: 4096,
            },
            workload: WorkloadKind::Synthetic,
            prefill_decode_ratio: None,
            chunk_size: 512,
            kv_block_tokens: 16,
            pue: 1.2,
            slo_ttft_s: 10.0,
            slo_e2e_s: 60.0,
            exec: ExecParams::default(),
            seed: 0xD15EA5E,
        }
    }
}

impl SimConfig {
    pub fn model_spec(&self) -> Result<&'static models::ModelSpec> {
        models::model(&self.model)
    }
    pub fn gpu_spec(&self) -> Result<&'static gpus::GpuSpec> {
        gpus::gpu(&self.gpu)
    }

    /// GPUs per replica.
    pub fn gpus_per_replica(&self) -> u32 {
        self.tp * self.pp
    }
    /// Total GPU count G = R·TP·PP (Eq. 2).
    pub fn total_gpus(&self) -> u32 {
        self.replicas * self.gpus_per_replica()
    }

    pub fn validate(&self) -> Result<()> {
        self.model_spec()?;
        self.gpu_spec()?;
        if self.tp == 0 || self.pp == 0 || self.replicas == 0 {
            bail!("tp/pp/replicas must be >= 1");
        }
        let m = self.model_spec()?;
        if m.num_layers % self.pp != 0 {
            bail!(
                "pp={} does not divide {} layers of {}",
                self.pp,
                m.num_layers,
                m.name
            );
        }
        if !(m.num_heads % self.tp == 0) {
            bail!("tp={} does not divide {} heads", self.tp, m.num_heads);
        }
        if self.batch_cap == 0 || self.batch_cap > 128 {
            bail!("batch_cap must be in 1..=128 (AOT oracle padding limit)");
        }
        if self.num_requests == 0 {
            bail!("num_requests must be > 0");
        }
        if let LengthDist::Zipf { min, max, .. } | LengthDist::Uniform { min, max } =
            &self.lengths
        {
            if min > max || *min == 0 {
                bail!("bad length range");
            }
        }
        self.workload.validate()?;
        if self.pue < 1.0 {
            bail!("pue < 1.0 is unphysical");
        }
        if self.slo_ttft_s <= 0.0 || self.slo_e2e_s <= 0.0 {
            bail!("SLO targets must be positive");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("model", self.model.as_str())
            .set("gpu", self.gpu.as_str())
            .set("tp", self.tp)
            .set("pp", self.pp)
            .set("replicas", self.replicas)
            .set(
                "scheduler",
                match self.scheduler {
                    SchedulerKind::Vllm => "vllm",
                    SchedulerKind::Sarathi => "sarathi",
                    SchedulerKind::Orca => "orca",
                },
            )
            .set(
                "router",
                match self.router {
                    RouterKind::RoundRobin => "round_robin",
                    RouterKind::LeastOutstanding => "least_outstanding",
                },
            )
            .set(
                "cost_model",
                match self.cost_model {
                    CostModelKind::Native => "native",
                    CostModelKind::Hlo => "hlo",
                    CostModelKind::Surface => "surface",
                },
            )
            .set("batch_cap", self.batch_cap)
            .set("max_tokens", self.max_tokens)
            .set("num_requests", self.num_requests)
            .set("chunk_size", self.chunk_size)
            .set("kv_block_tokens", self.kv_block_tokens)
            .set("pue", self.pue)
            .set("slo_ttft_s", self.slo_ttft_s)
            .set("slo_e2e_s", self.slo_e2e_s)
            .set("seed", self.seed);
        let mut arr = Value::obj();
        match &self.arrival {
            Arrival::Poisson { qps } => {
                arr.set("kind", "poisson").set("qps", *qps);
            }
            Arrival::Gamma { qps, cv } => {
                arr.set("kind", "gamma").set("qps", *qps).set("cv", *cv);
            }
            Arrival::Batch => {
                arr.set("kind", "batch");
            }
        }
        v.set("arrival", arr);
        let mut len = Value::obj();
        match &self.lengths {
            LengthDist::Zipf { theta, min, max } => {
                len.set("kind", "zipf")
                    .set("theta", *theta)
                    .set("min", *min)
                    .set("max", *max);
            }
            LengthDist::Fixed { total } => {
                len.set("kind", "fixed").set("total", *total);
            }
            LengthDist::Uniform { min, max } => {
                len.set("kind", "uniform").set("min", *min).set("max", *max);
            }
        }
        v.set("lengths", len);
        let mut wl = Value::obj();
        match &self.workload {
            WorkloadKind::Trace { path, time_scale, repeat } => {
                wl.set("kind", "trace")
                    .set("path", path.as_str())
                    .set("time_scale", *time_scale)
                    .set("repeat", *repeat);
            }
            WorkloadKind::Mix(_) => {
                wl.set("kind", "mix").set("spec", self.workload.spec().as_str());
            }
            other => {
                wl.set("kind", other.spec().as_str());
            }
        }
        v.set("workload", wl);
        if let Some(r) = self.prefill_decode_ratio {
            v.set("prefill_decode_ratio", r);
        }
        let mut ex = Value::obj();
        ex.set("flops_eff", self.exec.flops_eff)
            .set("mem_eff", self.exec.mem_eff)
            .set("t_overhead", self.exec.t_overhead)
            .set("layer_overhead", self.exec.layer_overhead)
            .set("rf_noise_std", self.exec.rf_noise_std);
        v.set("exec", ex);
        v
    }

    pub fn from_json(v: &Value) -> Result<SimConfig> {
        let d = SimConfig::default();
        let gs = |k: &str, dv: &str| -> String {
            v.get(k).and_then(|x| x.as_str()).unwrap_or(dv).to_string()
        };
        let gf = |k: &str, dv: f64| v.get(k).and_then(|x| x.as_f64()).unwrap_or(dv);
        let gu = |k: &str, dv: u64| v.get(k).and_then(|x| x.as_u64()).unwrap_or(dv);

        let arrival = match v.get("arrival") {
            None => d.arrival.clone(),
            Some(a) => match a.get("kind").and_then(|x| x.as_str()) {
                Some("poisson") | None => Arrival::Poisson {
                    qps: a.get("qps").and_then(|x| x.as_f64()).unwrap_or(6.45),
                },
                Some("gamma") => Arrival::Gamma {
                    qps: a.get("qps").and_then(|x| x.as_f64()).unwrap_or(6.45),
                    cv: a.get("cv").and_then(|x| x.as_f64()).unwrap_or(2.0),
                },
                Some("batch") => Arrival::Batch,
                Some(k) => bail!("unknown arrival kind '{k}'"),
            },
        };
        let lengths = match v.get("lengths") {
            None => d.lengths.clone(),
            Some(l) => match l.get("kind").and_then(|x| x.as_str()) {
                Some("zipf") | None => LengthDist::Zipf {
                    theta: l.get("theta").and_then(|x| x.as_f64()).unwrap_or(0.6),
                    min: l.get("min").and_then(|x| x.as_u64()).unwrap_or(128),
                    max: l.get("max").and_then(|x| x.as_u64()).unwrap_or(4096),
                },
                Some("fixed") => LengthDist::Fixed {
                    total: l
                        .get("total")
                        .and_then(|x| x.as_u64())
                        .context("fixed lengths need 'total'")?,
                },
                Some("uniform") => LengthDist::Uniform {
                    min: l.get("min").and_then(|x| x.as_u64()).unwrap_or(128),
                    max: l.get("max").and_then(|x| x.as_u64()).unwrap_or(4096),
                },
                Some(k) => bail!("unknown length kind '{k}'"),
            },
        };
        let workload = match v.get("workload") {
            None => d.workload.clone(),
            Some(w) => match w.get("kind").and_then(|x| x.as_str()) {
                None => d.workload.clone(),
                Some("trace") => WorkloadKind::Trace {
                    path: w.req_str("path")?.to_string(),
                    time_scale: w.get("time_scale").and_then(|x| x.as_f64()).unwrap_or(1.0),
                    repeat: w.get("repeat").and_then(|x| x.as_u64()).unwrap_or(1) as u32,
                },
                Some("mix") => WorkloadKind::parse(w.req_str("spec")?)?,
                Some(k) => WorkloadKind::parse(k)?,
            },
        };
        let exec = match v.get("exec") {
            None => d.exec.clone(),
            Some(e) => ExecParams {
                flops_eff: e.get("flops_eff").and_then(|x| x.as_f64()).unwrap_or(d.exec.flops_eff),
                mem_eff: e.get("mem_eff").and_then(|x| x.as_f64()).unwrap_or(d.exec.mem_eff),
                t_overhead: e
                    .get("t_overhead")
                    .and_then(|x| x.as_f64())
                    .unwrap_or(d.exec.t_overhead),
                layer_overhead: e
                    .get("layer_overhead")
                    .and_then(|x| x.as_f64())
                    .unwrap_or(d.exec.layer_overhead),
                rf_noise_std: e
                    .get("rf_noise_std")
                    .and_then(|x| x.as_f64())
                    .unwrap_or(d.exec.rf_noise_std),
            },
        };
        let cfg = SimConfig {
            model: gs("model", &d.model),
            gpu: gs("gpu", &d.gpu),
            tp: gu("tp", d.tp as u64) as u32,
            pp: gu("pp", d.pp as u64) as u32,
            replicas: gu("replicas", d.replicas as u64) as u32,
            scheduler: match gs("scheduler", "vllm").as_str() {
                "vllm" => SchedulerKind::Vllm,
                "sarathi" => SchedulerKind::Sarathi,
                "orca" => SchedulerKind::Orca,
                k => bail!("unknown scheduler '{k}'"),
            },
            router: match gs("router", "round_robin").as_str() {
                "round_robin" => RouterKind::RoundRobin,
                "least_outstanding" => RouterKind::LeastOutstanding,
                k => bail!("unknown router '{k}'"),
            },
            cost_model: match gs("cost_model", "hlo").as_str() {
                "native" => CostModelKind::Native,
                "hlo" => CostModelKind::Hlo,
                "surface" => CostModelKind::Surface,
                k => bail!("unknown cost model '{k}'"),
            },
            batch_cap: gu("batch_cap", d.batch_cap as u64) as usize,
            max_tokens: gu("max_tokens", d.max_tokens),
            num_requests: gu("num_requests", d.num_requests),
            arrival,
            lengths,
            workload,
            prefill_decode_ratio: v.get("prefill_decode_ratio").and_then(|x| x.as_f64()),
            chunk_size: gu("chunk_size", d.chunk_size),
            kv_block_tokens: gu("kv_block_tokens", d.kv_block_tokens),
            pue: gf("pue", d.pue),
            slo_ttft_s: gf("slo_ttft_s", d.slo_ttft_s),
            slo_e2e_s: gf("slo_e2e_s", d.slo_e2e_s),
            exec,
            seed: gu("seed", d.seed),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<SimConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let v = json::parse(&text)?;
        Self::from_json(&v)
    }
}

/// The Vessim-side co-simulation configuration (Table 1, panel b).
#[derive(Debug, Clone, PartialEq)]
pub struct CosimConfig {
    /// Grid region label (the paper: CAISO-North).
    pub location: String,
    /// Installed solar capacity, W (paper: 600).
    pub solar_capacity_w: f64,
    /// Battery usable capacity, Wh (paper: 100).
    pub battery_wh: f64,
    pub soc_init: f64,
    pub soc_min: f64,
    pub soc_max: f64,
    /// Battery power limits, W (C-rate equivalent).
    pub max_charge_w: f64,
    pub max_discharge_w: f64,
    pub charge_eff: f64,
    pub discharge_eff: f64,
    /// Co-simulation step, seconds (paper: 1 minute).
    pub interval_s: f64,
    /// Carbon-intensity thresholds, gCO₂/kWh (paper: 100 / 200).
    pub ci_low: f64,
    pub ci_high: f64,
    /// Mean grid carbon intensity for the synthetic trace
    /// (paper measured 418.2 gCO₂/kWh average over the run).
    pub ci_mean: f64,
    /// Hour-of-day (UTC-ish sim time) the workload starts.
    pub start_hour: f64,
    /// Per-watt-hour overhead for moving load to a remote region
    /// (network + marshalling), as a fraction of the moved energy.
    pub transfer_overhead: f64,
    pub seed: u64,
}

impl Default for CosimConfig {
    /// The paper's Table 1 (panel b) integration parameters.
    fn default() -> Self {
        CosimConfig {
            location: "CAISO-North".into(),
            solar_capacity_w: 600.0,
            battery_wh: 100.0,
            soc_init: 0.5,
            soc_min: 0.2,
            soc_max: 0.8,
            max_charge_w: 100.0,
            max_discharge_w: 100.0,
            charge_eff: 0.95,
            discharge_eff: 0.95,
            interval_s: 60.0,
            ci_low: 100.0,
            ci_high: 200.0,
            ci_mean: 418.2,
            start_hour: 6.0,
            transfer_overhead: 0.05,
            seed: 0xCA150,
        }
    }
}

impl CosimConfig {
    pub fn battery_params(&self) -> [f32; 8] {
        [
            self.battery_wh as f32,
            self.soc_min as f32,
            self.soc_max as f32,
            self.max_charge_w as f32,
            self.max_discharge_w as f32,
            self.charge_eff as f32,
            self.discharge_eff as f32,
            self.interval_s as f32,
        ]
    }

    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.soc_init)
            || !(0.0..=1.0).contains(&self.soc_min)
            || !(0.0..=1.0).contains(&self.soc_max)
            || self.soc_min >= self.soc_max
        {
            bail!("bad SoC bounds");
        }
        if self.battery_wh <= 0.0 || self.interval_s <= 0.0 {
            bail!("battery_wh and interval_s must be positive");
        }
        if self.ci_low >= self.ci_high {
            bail!("ci_low must be < ci_high");
        }
        if self.transfer_overhead < 0.0 {
            bail!("transfer_overhead must be >= 0");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("location", self.location.as_str())
            .set("solar_capacity_w", self.solar_capacity_w)
            .set("battery_wh", self.battery_wh)
            .set("soc_init", self.soc_init)
            .set("soc_min", self.soc_min)
            .set("soc_max", self.soc_max)
            .set("max_charge_w", self.max_charge_w)
            .set("max_discharge_w", self.max_discharge_w)
            .set("charge_eff", self.charge_eff)
            .set("discharge_eff", self.discharge_eff)
            .set("interval_s", self.interval_s)
            .set("ci_low", self.ci_low)
            .set("ci_high", self.ci_high)
            .set("ci_mean", self.ci_mean)
            .set("start_hour", self.start_hour)
            .set("transfer_overhead", self.transfer_overhead)
            .set("seed", self.seed);
        v
    }

    pub fn from_json(v: &Value) -> Result<CosimConfig> {
        let d = CosimConfig::default();
        let gf = |k: &str, dv: f64| v.get(k).and_then(|x| x.as_f64()).unwrap_or(dv);
        let cfg = CosimConfig {
            location: v
                .get("location")
                .and_then(|x| x.as_str())
                .unwrap_or(&d.location)
                .to_string(),
            solar_capacity_w: gf("solar_capacity_w", d.solar_capacity_w),
            battery_wh: gf("battery_wh", d.battery_wh),
            soc_init: gf("soc_init", d.soc_init),
            soc_min: gf("soc_min", d.soc_min),
            soc_max: gf("soc_max", d.soc_max),
            max_charge_w: gf("max_charge_w", d.max_charge_w),
            max_discharge_w: gf("max_discharge_w", d.max_discharge_w),
            charge_eff: gf("charge_eff", d.charge_eff),
            discharge_eff: gf("discharge_eff", d.discharge_eff),
            interval_s: gf("interval_s", d.interval_s),
            ci_low: gf("ci_low", d.ci_low),
            ci_high: gf("ci_high", d.ci_high),
            ci_mean: gf("ci_mean", d.ci_mean),
            start_hour: gf("start_hour", d.start_hour),
            transfer_overhead: gf("transfer_overhead", d.transfer_overhead),
            seed: v.get("seed").and_then(|x| x.as_u64()).unwrap_or(d.seed),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Which fleet-scaling policy the autoscaler runs (DESIGN.md §6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScalingPolicyKind {
    /// Fixed fleet (the paper's setting; autoscaling disabled).
    Static,
    /// Queue-depth-driven reactive scaling.
    Reactive,
    /// SLO-guarded carbon-aware scaling: shed capacity when the grid
    /// is dirty unless the SLO would be violated.
    CarbonAware,
    /// Fleet size follows solar availability (with an SLO floor).
    SolarFollowing,
}

impl ScalingPolicyKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ScalingPolicyKind::Static => "static",
            ScalingPolicyKind::Reactive => "reactive",
            ScalingPolicyKind::CarbonAware => "carbon_aware",
            ScalingPolicyKind::SolarFollowing => "solar_following",
        }
    }

    pub fn parse(s: &str) -> Result<ScalingPolicyKind> {
        Ok(match s {
            "static" => ScalingPolicyKind::Static,
            "reactive" => ScalingPolicyKind::Reactive,
            "carbon_aware" | "carbon-aware" | "carbon" => ScalingPolicyKind::CarbonAware,
            "solar_following" | "solar-following" | "solar" => ScalingPolicyKind::SolarFollowing,
            k => bail!("unknown scaling policy '{k}'"),
        })
    }
}

/// Autoscaling subsystem configuration (DESIGN.md §6): fleet bounds,
/// decision cadence, replica cold-start, and the queue/SLO thresholds
/// the policies consult.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleConfig {
    pub policy: ScalingPolicyKind,
    /// Fleet-size bounds; the controller clamps every decision into
    /// [min_replicas, max_replicas].
    pub min_replicas: u32,
    pub max_replicas: u32,
    /// Seconds between scaling decisions.
    pub decision_interval_s: f64,
    /// Provision→online delay (instance boot + weight load); the
    /// replica draws idle power while cold-starting.
    pub cold_start_s: f64,
    /// Per-replica queued requests above which policies scale up.
    pub queue_high: f64,
    /// Per-replica queued requests below which scale-down is considered.
    pub queue_low: f64,
    /// Running requests per replica below which a reactive scale-down
    /// is allowed (consolidation watermark).
    pub run_low: f64,
    /// Fraction of the SLO targets treated as "pressure": recent p99
    /// latencies above `slo * slo_margin` veto shedding and force a
    /// scale-up.
    pub slo_margin: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            policy: ScalingPolicyKind::Reactive,
            min_replicas: 1,
            max_replicas: 4,
            decision_interval_s: 120.0,
            cold_start_s: 60.0,
            queue_high: 8.0,
            queue_low: 2.0,
            run_low: 8.0,
            slo_margin: 0.8,
        }
    }
}

impl AutoscaleConfig {
    pub fn validate(&self) -> Result<()> {
        if self.min_replicas == 0 {
            bail!("min_replicas must be >= 1");
        }
        if self.max_replicas < self.min_replicas {
            bail!(
                "max_replicas {} < min_replicas {}",
                self.max_replicas,
                self.min_replicas
            );
        }
        if self.decision_interval_s <= 0.0 {
            bail!("decision_interval_s must be positive");
        }
        if self.cold_start_s < 0.0 {
            bail!("cold_start_s must be >= 0");
        }
        if self.queue_low > self.queue_high {
            bail!("queue_low must be <= queue_high");
        }
        if !(0.0..=1.0).contains(&self.slo_margin) {
            bail!("slo_margin must be in [0, 1]");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("policy", self.policy.as_str())
            .set("min_replicas", self.min_replicas)
            .set("max_replicas", self.max_replicas)
            .set("decision_interval_s", self.decision_interval_s)
            .set("cold_start_s", self.cold_start_s)
            .set("queue_high", self.queue_high)
            .set("queue_low", self.queue_low)
            .set("run_low", self.run_low)
            .set("slo_margin", self.slo_margin);
        v
    }

    pub fn from_json(v: &Value) -> Result<AutoscaleConfig> {
        let d = AutoscaleConfig::default();
        let gf = |k: &str, dv: f64| v.get(k).and_then(|x| x.as_f64()).unwrap_or(dv);
        let gu = |k: &str, dv: u64| v.get(k).and_then(|x| x.as_u64()).unwrap_or(dv);
        let cfg = AutoscaleConfig {
            policy: match v.get("policy").and_then(|x| x.as_str()) {
                None => d.policy,
                Some(s) => ScalingPolicyKind::parse(s)?,
            },
            min_replicas: gu("min_replicas", d.min_replicas as u64) as u32,
            max_replicas: gu("max_replicas", d.max_replicas as u64) as u32,
            decision_interval_s: gf("decision_interval_s", d.decision_interval_s),
            cold_start_s: gf("cold_start_s", d.cold_start_s),
            queue_high: gf("queue_high", d.queue_high),
            queue_low: gf("queue_low", d.queue_low),
            run_low: gf("run_low", d.run_low),
            slo_margin: gf("slo_margin", d.slo_margin),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_table1a() {
        let c = SimConfig::default();
        assert_eq!(c.model, "llama3-8b");
        assert_eq!(c.gpu, "a100-80g");
        assert_eq!((c.tp, c.pp), (1, 1));
        assert_eq!(c.batch_cap, 128);
        assert_eq!(c.max_tokens, 4096);
        assert_eq!(c.num_requests, 1024);
        assert_eq!(c.arrival.qps(), 6.45);
        assert_eq!(c.pue, 1.2);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn default_cosim_matches_paper_table1b() {
        let c = CosimConfig::default();
        assert_eq!(c.solar_capacity_w, 600.0);
        assert_eq!(c.battery_wh, 100.0);
        assert_eq!((c.soc_min, c.soc_max), (0.2, 0.8));
        assert_eq!((c.ci_low, c.ci_high), (100.0, 200.0));
        assert_eq!(c.interval_s, 60.0);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn sim_json_roundtrip() {
        let mut c = SimConfig::default();
        c.tp = 2;
        c.pp = 2;
        c.scheduler = SchedulerKind::Sarathi;
        c.arrival = Arrival::Gamma { qps: 3.0, cv: 1.5 };
        c.lengths = LengthDist::Fixed { total: 2048 };
        c.prefill_decode_ratio = Some(20.0);
        c.exec.rf_noise_std = 0.05;
        let back = SimConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn cosim_json_roundtrip() {
        let mut c = CosimConfig::default();
        c.solar_capacity_w = 1200.0;
        c.start_hour = 0.0;
        c.transfer_overhead = 0.12;
        let back = CosimConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn workload_kind_parse_and_spec_roundtrip() {
        for s in ["synthetic", "chat", "rag", "agentic", "tenants", "trace:/tmp/t.csv"] {
            let k = WorkloadKind::parse(s).unwrap();
            assert_eq!(k.spec(), s);
            assert_eq!(WorkloadKind::parse(&k.spec()).unwrap(), k);
        }
        let mix = WorkloadKind::parse("mix:chat=2,rag=1.5,tenants").unwrap();
        assert_eq!(
            mix,
            WorkloadKind::Mix(vec![
                ("chat".into(), 2.0),
                ("rag".into(), 1.5),
                ("tenants".into(), 1.0),
            ])
        );
        assert_eq!(WorkloadKind::parse(&mix.spec()).unwrap(), mix);
        assert!(WorkloadKind::parse("bogus").is_err());
        assert!(WorkloadKind::parse("mix:chat=oops").is_err());
    }

    #[test]
    fn workload_kind_validate() {
        assert!(WorkloadKind::Synthetic.validate().is_ok());
        let bad_scale = WorkloadKind::Trace {
            path: "t.csv".into(),
            time_scale: f64::NAN,
            repeat: 1,
        };
        assert!(bad_scale.validate().is_err());
        let no_path = WorkloadKind::Trace {
            path: String::new(),
            time_scale: 1.0,
            repeat: 1,
        };
        assert!(no_path.validate().is_err());
        assert!(WorkloadKind::Mix(vec![]).validate().is_err());
        assert!(WorkloadKind::Mix(vec![("trace".into(), 1.0)]).validate().is_err());
        assert!(WorkloadKind::Mix(vec![("chat".into(), -1.0)]).validate().is_err());
        assert!(WorkloadKind::Mix(vec![("chat".into(), 1.0)]).validate().is_ok());
    }

    #[test]
    fn sim_json_roundtrips_workload_variants() {
        for wl in [
            WorkloadKind::Chat,
            WorkloadKind::Tenants,
            WorkloadKind::Trace {
                path: "traces/azure.jsonl".into(),
                time_scale: 0.25,
                repeat: 3,
            },
            WorkloadKind::Mix(vec![("chat".into(), 2.0), ("rag".into(), 0.5)]),
        ] {
            let mut c = SimConfig::default();
            c.workload = wl;
            let back = SimConfig::from_json(&c.to_json()).unwrap();
            assert_eq!(back, c);
        }
        // Absent field defaults to the synthetic generator (old
        // config files stay loadable).
        let v = json::parse("{}").unwrap();
        assert_eq!(SimConfig::from_json(&v).unwrap().workload, WorkloadKind::Synthetic);
    }

    #[test]
    fn validate_rejects_bad_pp() {
        let mut c = SimConfig::default();
        c.pp = 3; // 32 layers not divisible by 3
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_batch_cap() {
        let mut c = SimConfig::default();
        c.batch_cap = 0;
        assert!(c.validate().is_err());
        c.batch_cap = 256; // above AOT padding limit
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_soc_inversion() {
        let mut c = CosimConfig::default();
        c.soc_min = 0.9;
        assert!(c.validate().is_err());
    }

    #[test]
    fn autoscale_json_roundtrip() {
        let mut c = AutoscaleConfig::default();
        c.policy = ScalingPolicyKind::CarbonAware;
        c.max_replicas = 8;
        c.cold_start_s = 45.0;
        let back = AutoscaleConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn autoscale_validate_rejects_inverted_bounds() {
        let mut c = AutoscaleConfig::default();
        c.min_replicas = 4;
        c.max_replicas = 2;
        assert!(c.validate().is_err());
        c = AutoscaleConfig::default();
        c.min_replicas = 0;
        assert!(c.validate().is_err());
        c = AutoscaleConfig::default();
        c.decision_interval_s = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn policy_kind_parse_roundtrip() {
        for k in [
            ScalingPolicyKind::Static,
            ScalingPolicyKind::Reactive,
            ScalingPolicyKind::CarbonAware,
            ScalingPolicyKind::SolarFollowing,
        ] {
            assert_eq!(ScalingPolicyKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(ScalingPolicyKind::parse("bogus").is_err());
    }

    #[test]
    fn slo_targets_roundtrip_and_validate() {
        let mut c = SimConfig::default();
        c.slo_ttft_s = 2.5;
        c.slo_e2e_s = 30.0;
        let back = SimConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
        c.slo_ttft_s = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn total_gpus_eq2() {
        let mut c = SimConfig::default();
        c.tp = 2;
        c.pp = 2;
        c.replicas = 3;
        assert_eq!(c.total_gpus(), 12); // G = R * TP * PP
    }
}
