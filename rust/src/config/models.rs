//! LLM architecture registry — the six models the paper evaluates
//! (Experiment 1 sweeps 2.7B…72B; the defaults use Meta-Llama-3-8B and
//! the co-simulation case study Llama-2-7B).
//!
//! Architecture numbers are the public model-card values.

use anyhow::{bail, Result};

/// Transformer architecture description (decoder-only).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Registry key, e.g. "llama3-8b".
    pub name: &'static str,
    /// Human-readable name as in the paper.
    pub display: &'static str,
    pub num_layers: u32,
    pub hidden: u32,
    pub ffn: u32,
    pub num_heads: u32,
    pub num_kv_heads: u32,
    pub vocab: u32,
    /// MLP matmul count: 3.0 for SwiGLU (Llama family), 2.0 for the
    /// classic GELU MLP (Phi-2). Folded into an effective ffn width so
    /// the AOT kernel interface stays SwiGLU-shaped.
    pub mlp_mult: f64,
    /// Nominal parameter count (billions), for display/grouping.
    pub params_b: f64,
}

impl ModelSpec {
    /// KV-projection width (GQA-aware).
    pub fn kv_dim(&self) -> f64 {
        self.hidden as f64 * self.num_kv_heads as f64 / self.num_heads as f64
    }

    /// SwiGLU-equivalent FFN width (the AOT kernels assume three
    /// h x ffn matmuls; non-SwiGLU models are rescaled).
    pub fn ffn_eff(&self) -> f64 {
        self.ffn as f64 * self.mlp_mult / 3.0
    }

    /// Approximate parameter bytes in bf16 — mirrors
    /// `ref_weight_bytes` in python/compile/kernels/ref.py.
    pub fn weight_bytes(&self) -> f64 {
        let h = self.hidden as f64;
        let per_layer = h * (2.0 * h + 2.0 * self.kv_dim()) + 3.0 * h * self.ffn_eff();
        let embed = 2.0 * h * self.vocab as f64;
        2.0 * (self.num_layers as f64 * per_layer + embed)
    }

    /// Per-token KV-cache bytes (both K and V, bf16, all layers).
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.num_layers as f64 * self.kv_dim() * 2.0
    }

    /// Dense forward FLOPs per token excluding attention-over-context
    /// (projections + MLP + LM head); context-dependent attention is
    /// added per-request by the execution model.
    pub fn dense_flops_per_token(&self) -> f64 {
        let h = self.hidden as f64;
        let proj = 2.0 * h * (2.0 * h + 2.0 * self.kv_dim());
        let mlp = 6.0 * h * self.ffn_eff();
        self.num_layers as f64 * (proj + mlp) + 2.0 * h * self.vocab as f64
    }

    /// The mp[8] parameter vector consumed by the AOT stage oracle
    /// (layout shared with python/compile/kernels/ref.py).
    pub fn param_vec(&self, tp: u32, pp: u32) -> [f32; 8] {
        [
            self.num_layers as f32,
            self.hidden as f32,
            self.ffn_eff() as f32,
            self.num_heads as f32,
            self.num_kv_heads as f32,
            self.vocab as f32,
            tp as f32,
            pp as f32,
        ]
    }
}

/// The models used in the paper's evaluation (Fig. 2 legend + defaults).
pub const MODELS: &[ModelSpec] = &[
    ModelSpec {
        name: "phi-2",
        display: "Phi-2 (2.7B)",
        num_layers: 32,
        hidden: 2560,
        ffn: 10240,
        num_heads: 32,
        num_kv_heads: 32,
        vocab: 51200,
        mlp_mult: 2.0,
        params_b: 2.7,
    },
    ModelSpec {
        name: "llama2-7b",
        display: "Llama-2-7B-hf",
        num_layers: 32,
        hidden: 4096,
        ffn: 11008,
        num_heads: 32,
        num_kv_heads: 32,
        vocab: 32000,
        mlp_mult: 3.0,
        params_b: 6.7,
    },
    ModelSpec {
        name: "llama3-8b",
        display: "Meta-Llama-3-8B",
        num_layers: 32,
        hidden: 4096,
        ffn: 14336,
        num_heads: 32,
        num_kv_heads: 8,
        vocab: 128256,
        mlp_mult: 3.0,
        params_b: 8.0,
    },
    ModelSpec {
        name: "codellama-34b",
        display: "CodeLlama-34B",
        num_layers: 48,
        hidden: 8192,
        ffn: 22016,
        num_heads: 64,
        num_kv_heads: 8,
        vocab: 32000,
        mlp_mult: 3.0,
        params_b: 33.7,
    },
    ModelSpec {
        name: "llama3-70b",
        display: "LLaMA-3-70B",
        num_layers: 80,
        hidden: 8192,
        ffn: 28672,
        num_heads: 64,
        num_kv_heads: 8,
        vocab: 128256,
        mlp_mult: 3.0,
        params_b: 70.6,
    },
    ModelSpec {
        name: "qwen-72b",
        display: "Qwen-72B",
        num_layers: 80,
        hidden: 8192,
        ffn: 24576,
        num_heads: 64,
        num_kv_heads: 64,
        vocab: 152064,
        mlp_mult: 3.0,
        params_b: 72.3,
    },
];

/// Look a model up by registry key.
pub fn model(name: &str) -> Result<&'static ModelSpec> {
    match MODELS.iter().find(|m| m.name == name) {
        Some(m) => Ok(m),
        None => bail!(
            "unknown model '{name}'; known: {}",
            MODELS.iter().map(|m| m.name).collect::<Vec<_>>().join(", ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_papers_six_models() {
        assert_eq!(MODELS.len(), 6);
        for key in [
            "phi-2",
            "llama2-7b",
            "llama3-8b",
            "codellama-34b",
            "llama3-70b",
            "qwen-72b",
        ] {
            assert!(model(key).is_ok(), "{key} missing");
        }
    }

    #[test]
    fn unknown_model_is_error() {
        assert!(model("gpt-99").is_err());
    }

    #[test]
    fn weight_bytes_close_to_nominal_param_count() {
        // bf16 bytes / 2 = params; must be within ~15% of the nominal
        // billions (approximation ignores norms/biases).
        for m in MODELS {
            let params_b = m.weight_bytes() / 2.0 / 1e9;
            let rel = (params_b - m.params_b).abs() / m.params_b;
            assert!(
                rel < 0.15,
                "{}: approx {params_b:.1}B vs nominal {}B",
                m.name,
                m.params_b
            );
        }
    }

    #[test]
    fn gqa_reduces_kv_footprint() {
        let l3 = model("llama3-8b").unwrap(); // 8 kv heads
        let l2 = model("llama2-7b").unwrap(); // 32 kv heads (MHA)
        assert!(l3.kv_bytes_per_token() < l2.kv_bytes_per_token() / 2.0);
    }

    #[test]
    fn param_vec_layout() {
        let m = model("llama3-8b").unwrap();
        let v = m.param_vec(2, 4);
        assert_eq!(v[0], 32.0);
        assert_eq!(v[1], 4096.0);
        assert_eq!(v[6], 2.0);
        assert_eq!(v[7], 4.0);
    }

    #[test]
    fn models_ordered_by_size() {
        for w in MODELS.windows(2) {
            assert!(w[0].params_b <= w[1].params_b);
        }
    }
}
