//! ASCII line/scatter charts: render experiment series directly in the
//! terminal so `repro report` shows figure *shapes* (saturation,
//! plateaus, crossovers) without leaving the console.

/// Render one or more named series over a shared x-axis as an ASCII
/// chart of the given size. Series are drawn with distinct glyphs.
pub fn line_chart(
    title: &str,
    x: &[f64],
    series: &[(&str, &[f64])],
    width: usize,
    height: usize,
) -> String {
    assert!(width >= 16 && height >= 4);
    assert!(!x.is_empty());
    for (_, ys) in series {
        assert_eq!(ys.len(), x.len(), "series length mismatch");
    }
    const GLYPHS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];

    let (xmin, xmax) = bounds(x);
    let mut ymin = f64::INFINITY;
    let mut ymax = f64::NEG_INFINITY;
    for (_, ys) in series {
        let (lo, hi) = bounds(ys);
        ymin = ymin.min(lo);
        ymax = ymax.max(hi);
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    if (xmax - xmin).abs() < 1e-12 {
        return format!("{title}\n(single x value; nothing to plot)\n");
    }

    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        let glyph = GLYPHS[si % GLYPHS.len()];
        // Plot each sample, connecting consecutive points coarsely.
        let to_cell = |xi: f64, yi: f64| -> (usize, usize) {
            let cx = ((xi - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let cy = ((yi - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
            (cx.min(width - 1), height - 1 - cy.min(height - 1))
        };
        for i in 0..x.len() {
            let (cx, cy) = to_cell(x[i], ys[i]);
            grid[cy][cx] = glyph;
            if i > 0 {
                // Linear interpolation between samples for continuity.
                let steps = 2 * width;
                for s in 0..steps {
                    let a = s as f64 / steps as f64;
                    let xi = x[i - 1] + a * (x[i] - x[i - 1]);
                    let yi = ys[i - 1] + a * (ys[i] - ys[i - 1]);
                    let (cx, cy) = to_cell(xi, yi);
                    if grid[cy][cx] == ' ' {
                        grid[cy][cx] = glyph;
                    }
                }
            }
        }
    }

    let mut out = String::new();
    out.push_str(&format!("{title}\n"));
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (name, _))| format!("{} {name}", GLYPHS[i % GLYPHS.len()]))
        .collect();
    out.push_str(&format!("  [{}]\n", legend.join("  ")));
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{ymax:>10.3}")
        } else if r == height - 1 {
            format!("{ymin:>10.3}")
        } else {
            " ".repeat(10)
        };
        out.push_str(&format!("{label} |{}\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!(
        "{} +{}\n{}  {:<10.3}{:>width$.3}\n",
        " ".repeat(10),
        "-".repeat(width),
        " ".repeat(10),
        xmin,
        xmax,
        width = width - 10
    ));
    out
}

fn bounds(v: &[f64]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in v {
        if x.is_finite() {
            lo = lo.min(x);
            hi = hi.max(x);
        }
    }
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_monotone_series() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| v * v).collect();
        let c = line_chart("parabola", &x, &[("y=x^2", &y)], 40, 10);
        assert!(c.contains("parabola"));
        assert!(c.contains("* y=x^2"));
        // Max label present.
        assert!(c.contains("81.000"));
        // The last row (near ymin) has a glyph near the left edge.
        let lines: Vec<&str> = c.lines().collect();
        assert!(lines.len() > 10);
    }

    #[test]
    fn two_series_distinct_glyphs() {
        let x = [0.0, 1.0, 2.0];
        let a = [0.0, 1.0, 2.0];
        let b = [2.0, 1.0, 0.0];
        let c = line_chart("cross", &x, &[("up", &a), ("down", &b)], 30, 8);
        assert!(c.contains('*') && c.contains('o'));
    }

    #[test]
    fn flat_series_does_not_panic() {
        let x = [0.0, 1.0];
        let y = [5.0, 5.0];
        let c = line_chart("flat", &x, &[("f", &y)], 20, 5);
        assert!(c.contains("flat"));
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_rejected() {
        line_chart("bad", &[0.0, 1.0], &[("s", &[1.0][..])], 20, 5);
    }
}
