//! Report assembly: collect experiment outputs from a results
//! directory into one markdown document (used by `repro report`).

pub mod charts;

use crate::util::csv::Table;
use anyhow::Result;
use std::path::Path;

/// Known experiment ids in presentation order.
pub const EXPERIMENT_IDS: &[&str] = &[
    "fig1", "exp1", "exp2", "exp3", "exp4", "exp5", "casestudy", "ablation",
    "sched", "gpu", "autoscale",
];

/// Figure definitions rendered as ASCII charts in the report:
/// (experiment id, chart title, x column, y columns).
const FIGURES: &[(&str, &str, &str, &[&str])] = &[
    ("fig1", "Fig.1 — MFU vs QPS (plateau = saturation)", "qps", &["weighted_mfu"]),
    ("exp3", "Fig.4 — batch cap vs energy", "batch_cap", &["energy_kwh"]),
    ("exp4", "Fig.5 — QPS vs avg power (W)", "qps", &["avg_power_w"]),
    (
        "autoscale",
        "Autoscaling — emissions vs mean fleet size per policy",
        "mean_fleet",
        &["net_footprint_g", "slo_pct"],
    ),
];

/// Build a markdown report from whatever results exist under `dir`.
pub fn assemble(dir: &Path) -> Result<String> {
    let mut out = String::from("# vidur-energy experiment report\n");
    for id in EXPERIMENT_IDS {
        let csv = dir.join(id).join(format!("{id}.csv"));
        if !csv.exists() {
            continue;
        }
        let table = Table::load(&csv)?;
        out.push_str(&format!("\n## {id}\n\n"));
        let meta = dir.join(id).join("meta.json");
        if let Ok(text) = std::fs::read_to_string(&meta) {
            if let Ok(v) = crate::util::json::parse(&text) {
                if let Some(claim) = v
                    .get("paper_claim")
                    .or_else(|| v.get("description"))
                    .and_then(|x| x.as_str())
                {
                    out.push_str(&format!("> paper: {claim}\n\n"));
                }
            }
        }
        out.push_str(&table.to_markdown());
        // Attach ASCII figures where defined.
        for (fid, title, xcol, ycols) in FIGURES {
            if fid != id {
                continue;
            }
            if let Ok(x) = table.f64_col(xcol) {
                let mut ys: Vec<(String, Vec<f64>)> = Vec::new();
                for yc in *ycols {
                    if let Ok(y) = table.f64_col(yc) {
                        ys.push((yc.to_string(), y));
                    }
                }
                let series: Vec<(&str, &[f64])> = ys
                    .iter()
                    .map(|(n, v)| (n.as_str(), v.as_slice()))
                    .collect();
                if !series.is_empty() {
                    out.push_str("\n```\n");
                    out.push_str(&charts::line_chart(title, &x, &series, 64, 14));
                    out.push_str("```\n");
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::csv::Table;

    #[test]
    fn assembles_present_results_only() {
        let dir = std::env::temp_dir().join("vidur_energy_report_test");
        std::fs::create_dir_all(dir.join("fig1")).unwrap();
        let mut t = Table::new(&["qps", "mfu"]);
        t.push(&[5.0, 0.4]);
        t.save(dir.join("fig1").join("fig1.csv")).unwrap();
        let md = assemble(&dir).unwrap();
        assert!(md.contains("## fig1"));
        assert!(!md.contains("## exp1")); // absent results skipped
        std::fs::remove_dir_all(dir).ok();
    }
}
