//! Report assembly: collect experiment outputs from a results
//! directory into one markdown document (used by `repro report`).
//!
//! Shard-aware (DESIGN.md §9): an experiment directory carrying a
//! telemetry sidecar gets a request-latency summary line computed from
//! the (merged) sketches, and a directory that is still a single shard
//! (`shard: k/N`) is flagged so a partial grid is never mistaken for
//! the full figure — regenerate figures from the `repro merge` output,
//! not from one shard.
//!
//! The [`live`] module is the *during*-a-run counterpart (DESIGN.md
//! §10): `--watch` dashboards and the `repro watch` snapshot
//! aggregator, fed by the telemetry fan-out instead of result files.

pub mod charts;
pub mod live;

use crate::telemetry::ShardTelemetry;
use crate::util::csv::Table;
use anyhow::Result;
use std::path::Path;

/// Known experiment ids in presentation order.
pub const EXPERIMENT_IDS: &[&str] = &[
    "fig1", "exp1", "exp2", "exp3", "exp4", "exp5", "casestudy", "ablation",
    "sched", "gpu", "autoscale", "multiregion", "scenarios",
];

/// Figure definitions rendered as ASCII charts in the report:
/// (experiment id, chart title, x column, y columns).
const FIGURES: &[(&str, &str, &str, &[&str])] = &[
    ("fig1", "Fig.1 — MFU vs QPS (plateau = saturation)", "qps", &["weighted_mfu"]),
    ("exp3", "Fig.4 — batch cap vs energy", "batch_cap", &["energy_kwh"]),
    ("exp4", "Fig.5 — QPS vs avg power (W)", "qps", &["avg_power_w"]),
    (
        "autoscale",
        "Autoscaling — emissions vs mean fleet size per policy",
        "mean_fleet",
        &["net_footprint_g", "slo_pct"],
    ),
];

/// Build a markdown report from whatever results exist under `dir`.
pub fn assemble(dir: &Path) -> Result<String> {
    let mut out = String::from("# vidur-energy experiment report\n");
    for id in EXPERIMENT_IDS {
        let csv = dir.join(id).join(format!("{id}.csv"));
        if !csv.exists() {
            continue;
        }
        let table = Table::load(&csv)?;
        out.push_str(&format!("\n## {id}\n\n"));
        let meta = dir.join(id).join("meta.json");
        if let Ok(text) = std::fs::read_to_string(&meta) {
            if let Ok(v) = crate::util::json::parse(&text) {
                if let Some(claim) = v
                    .get("paper_claim")
                    .or_else(|| v.get("description"))
                    .and_then(|x| x.as_str())
                {
                    out.push_str(&format!("> paper: {claim}\n\n"));
                }
            }
        }
        // Telemetry sidecar: latency summary from the (merged)
        // sketches, plus a loud flag on partial grids — whether an
        // unmerged shard (`shard: k/N`) or a merge that was given only
        // a subset of the shards (shard dropped but cases incomplete).
        match ShardTelemetry::load(&dir.join(id)) {
            Ok(Some(tel)) => {
                if !tel.is_complete() {
                    let origin = match tel.shard {
                        Some(s) => format!("shard {s}"),
                        None => "incomplete merge".to_string(),
                    };
                    out.push_str(&format!(
                        "> **partial output — {origin}** ({} of {} cases); \
                         combine all shards with `repro merge` before reading \
                         figures off this table\n\n",
                        tel.cases.len(),
                        tel.total_cases
                    ));
                }
                let r = &tel.requests;
                if r.finished > 0 {
                    out.push_str(&format!(
                        "> telemetry: {} requests, ttft p50/p99 {:.3}/{:.3} s, \
                         e2e p99 {:.2} s (sketch ε = {:.0e})\n\n",
                        r.finished,
                        r.ttft_p50_s,
                        r.ttft_p99_s,
                        r.e2e_p99_s,
                        tel.sketches.e2e.epsilon()
                    ));
                }
            }
            Ok(None) => {}
            Err(e) => {
                // A corrupt sidecar must not silently demote a partial
                // grid to "looks complete".
                out.push_str(&format!(
                    "> **warning:** unreadable telemetry sidecar ({e:#}); \
                     if this directory came from a sharded run, its \
                     completeness cannot be checked\n\n"
                ));
            }
        }
        out.push_str(&table.to_markdown());
        // Attach ASCII figures where defined.
        for (fid, title, xcol, ycols) in FIGURES {
            if fid != id {
                continue;
            }
            if let Ok(x) = table.f64_col(xcol) {
                let mut ys: Vec<(String, Vec<f64>)> = Vec::new();
                for yc in *ycols {
                    if let Ok(y) = table.f64_col(yc) {
                        ys.push((yc.to_string(), y));
                    }
                }
                let series: Vec<(&str, &[f64])> = ys
                    .iter()
                    .map(|(n, v)| (n.as_str(), v.as_slice()))
                    .collect();
                if !series.is_empty() {
                    out.push_str("\n```\n");
                    out.push_str(&charts::line_chart(title, &x, &series, 64, 14));
                    out.push_str("```\n");
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::csv::Table;

    #[test]
    fn assembles_present_results_only() {
        let dir = std::env::temp_dir().join("vidur_energy_report_test");
        std::fs::create_dir_all(dir.join("fig1")).unwrap();
        let mut t = Table::new(&["qps", "mfu"]);
        t.push(&[5.0, 0.4]);
        t.save(dir.join("fig1").join("fig1.csv")).unwrap();
        let md = assemble(&dir).unwrap();
        assert!(md.contains("## fig1"));
        assert!(!md.contains("## exp1")); // absent results skipped
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn unmerged_shard_output_is_flagged() {
        use crate::sweep::ShardSpec;
        let dir = std::env::temp_dir().join("vidur_energy_report_shard_test");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(dir.join("exp3")).unwrap();
        let mut t = Table::new(&["batch_cap", "energy_kwh"]);
        t.push(&[8.0, 0.2]);
        t.save(dir.join("exp3").join("exp3.csv")).unwrap();
        let mut tel =
            ShardTelemetry::new("exp3", Some(ShardSpec::new(1, 4).unwrap()), 8);
        tel.cases = vec![1];
        tel.save(&dir.join("exp3")).unwrap();
        let md = assemble(&dir).unwrap();
        assert!(md.contains("partial output — shard 1/4"), "{md}");
        assert!(md.contains("repro merge"));

        // A merge that was fed only a subset of shards drops the shard
        // identity but is still incomplete — it must be flagged too.
        tel.shard = None;
        tel.save(&dir.join("exp3")).unwrap();
        let md = assemble(&dir).unwrap();
        assert!(md.contains("partial output — incomplete merge"), "{md}");

        // A corrupt sidecar must surface as a warning, not silence.
        std::fs::write(dir.join("exp3").join("telemetry.json"), "{ not json").unwrap();
        let md = assemble(&dir).unwrap();
        assert!(md.contains("unreadable telemetry sidecar"), "{md}");
        std::fs::remove_dir_all(dir).ok();
    }
}
