//! Live sweep observability (DESIGN.md §10): the view side of the
//! watch pipeline.
//!
//! The telemetry side (`telemetry::window`) produces [`Snapshot`]s;
//! this module decides what happens to them. One [`LiveView`] exists
//! per watched experiment run, shared (`Arc<Mutex>`) by every sweep
//! worker:
//!
//! * `--watch` / `--watch=stderr` — re-renders an in-place terminal
//!   dashboard on stderr (cases done/total, live QPS, rolling p50/p99
//!   TTFT, watts, cumulative kWh/gCO₂, shard id);
//! * `--watch=json:PATH` — appends one machine-readable JSONL line per
//!   snapshot, flushed immediately so `repro watch` can tail it from
//!   another process (or another machine, over a shared filesystem).
//!
//! `repro watch <dir-or-file>...` reads such JSONL files — one per
//! shard of a cross-machine sweep — and [`aggregate`]s them: per-case
//! *latest* snapshots are summed into experiment totals (cumulative
//! fields) and live rates (windowed fields of still-running cases), so
//! the operator sees one dashboard for the whole fleet. The final
//! aggregate of `done` snapshots equals the `meta.json` /
//! `telemetry.json` totals — asserted by `tests/watch_observer.rs` and
//! the CI watch-smoke.
//!
//! The watch configuration is process-global (set once from the CLI,
//! like `--jobs` and `--shard`) so experiment regenerators pick it up
//! without signature churn.

use crate::config::simconfig::SimConfig;
use crate::sweep::ShardSpec;
use crate::telemetry::window::{CaseWatch, Snapshot, SnapshotEmitter};
use crate::telemetry::{FanoutRequestSink, FanoutStageSink, RequestSink, StageSink};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Default JSONL file name looked up inside watch directories.
pub const WATCH_FILENAME: &str = "watch.jsonl";

/// Where snapshots go.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WatchTarget {
    /// In-place terminal dashboard on stderr.
    Stderr,
    /// Append JSONL snapshot lines to this path.
    Json(PathBuf),
}

/// The `--watch` configuration: target plus the sim-time emission
/// cadence and the rolling-window span the snapshots aggregate over.
#[derive(Debug, Clone, PartialEq)]
pub struct WatchConfig {
    pub target: WatchTarget,
    /// Sim-time seconds between snapshots of one case.
    pub cadence_s: f64,
    /// Rolling-window span for the windowed fields, sim-time seconds.
    pub window_s: f64,
}

impl WatchConfig {
    /// The bare `--watch` default: stderr dashboard, one snapshot per
    /// simulated minute, 5-minute rolling window (the bin and
    /// autoscaler-window scales next door).
    pub fn stderr() -> WatchConfig {
        WatchConfig {
            target: WatchTarget::Stderr,
            cadence_s: 60.0,
            window_s: 300.0,
        }
    }

    /// Parse the `--watch=<spec>` forms: `stderr` or `json:PATH`.
    pub fn parse(spec: &str) -> Result<WatchConfig> {
        let mut cfg = WatchConfig::stderr();
        if spec == "stderr" {
            return Ok(cfg);
        }
        if let Some(path) = spec.strip_prefix("json:") {
            if path.is_empty() {
                bail!("--watch=json: needs a path (e.g. --watch=json:watch.jsonl)");
            }
            cfg.target = WatchTarget::Json(PathBuf::from(path));
            return Ok(cfg);
        }
        bail!("--watch expects 'stderr' or 'json:PATH', got '{spec}'");
    }
}

/// Process-wide watch configuration (the CLI's `--watch`), mirroring
/// the `--jobs` / `--shard` globals next door.
static ACTIVE_WATCH: Mutex<Option<WatchConfig>> = Mutex::new(None);

/// Serializes tests that mutate the process-global watch (they live in
/// more than one module of this crate, and the libtest harness runs
/// them on parallel threads).
#[cfg(test)]
pub(crate) static WATCH_TEST_LOCK: Mutex<()> = Mutex::new(());

/// Set (or clear, with `None`) the process-wide watch configuration.
pub fn set_watch(cfg: Option<WatchConfig>) {
    *ACTIVE_WATCH.lock().unwrap() = cfg;
}

/// The process-wide watch configuration, if any.
pub fn active_watch() -> Option<WatchConfig> {
    ACTIVE_WATCH.lock().unwrap().clone()
}

enum ViewOutput {
    /// Terminal dashboard; remembers how many lines the last render
    /// used so the next one can redraw in place.
    Stderr { last_lines: usize },
    Json {
        w: std::io::BufWriter<std::fs::File>,
        /// A write failure is reported once (not once per snapshot) —
        /// a full disk mid-sweep must not fail the sweep, but it must
        /// not be silent either.
        warned: bool,
    },
}

/// Watch-log paths this process has already opened. The *first* open
/// of a path truncates it — a fresh invocation must not mix its
/// snapshot stream with a previous (possibly aborted) run's, whose
/// stale `done` lines would win the latest-per-case aggregation —
/// while later opens in the same process (`experiment all` runs one
/// `LiveView` per experiment) append to the shared file.
static OPENED_LOGS: Mutex<BTreeSet<PathBuf>> = Mutex::new(BTreeSet::new());

/// Process-wide snapshot sequence. One counter across every view, so
/// `seq` stays strictly increasing through a whole `experiment all`
/// log (several views appending to one shared file) — the per-file
/// well-formedness invariant the CI watch-smoke asserts.
static SNAPSHOT_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Observes every snapshot a [`LiveView`] emits, *after* the view
/// stamped the process-wide fields. Read-only by design: taps fan out
/// to observers (the serve plane's broadcast hub), they never alter
/// the stream the primary target renders/appends.
pub type SnapshotTap = Arc<dyn Fn(&Snapshot) + Send + Sync>;

/// Registered snapshot taps, keyed by registration id so a shutting-
/// down observer can remove exactly its own tap. Process-global like
/// the watch config: views are constructed deep inside experiment
/// regenerators, and threading an observer handle through them would
/// churn every signature for one observability seam.
static SNAPSHOT_TAPS: Mutex<Vec<(u64, SnapshotTap)>> = Mutex::new(Vec::new());
static SNAPSHOT_TAP_ID: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Register a tap called with every stamped snapshot any view emits
/// from now on. Returns the id to pass to [`remove_snapshot_tap`].
pub fn add_snapshot_tap(tap: SnapshotTap) -> u64 {
    let id = SNAPSHOT_TAP_ID.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
    SNAPSHOT_TAPS
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .push((id, tap));
    id
}

/// Remove a previously registered tap; unknown ids are a no-op (an
/// observer may race its own shutdown).
pub fn remove_snapshot_tap(id: u64) {
    SNAPSHOT_TAPS
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .retain(|(i, _)| *i != id);
}

/// The registered taps, cloned out of the lock — callers invoke them
/// unlocked so a slow tap never stalls registration (or another view's
/// emit beyond its own lock).
fn snapshot_taps() -> Vec<SnapshotTap> {
    SNAPSHOT_TAPS
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .iter()
        .map(|(_, t)| t.clone())
        .collect()
}

/// One watched experiment run's snapshot consumer. Stamps the
/// process-wide snapshot fields (`seq`, `cases_done`, `cases_total`)
/// and renders/appends. Shared across sweep workers behind
/// `Arc<Mutex>`.
pub struct LiveView {
    cfg: WatchConfig,
    experiment: String,
    shard: Option<String>,
    /// Full grid size across all shards (stamped into snapshots —
    /// the unit `repro watch` aggregates against).
    cases_total: u64,
    /// Cases *this process* owns (= total unless sharded) — the
    /// stderr dashboard's denominator, or a shard would count its
    /// local completions against the global grid and never look done.
    cases_owned: u64,
    done_cases: BTreeSet<u64>,
    /// Latest snapshot per case — maintained for the stderr dashboard
    /// only (the JSON path has no reader for it).
    latest: BTreeMap<u64, Snapshot>,
    out: ViewOutput,
}

impl LiveView {
    /// Open a view for one experiment run. A JSON target is truncated
    /// on its first open in this process (a fresh invocation never
    /// mixes with a previous run's stream) and appended to on later
    /// opens (`experiment all` runs one view per experiment over one
    /// shared file; every line is self-describing).
    pub fn open(
        cfg: &WatchConfig,
        experiment: &str,
        cases_total: u64,
        cases_owned: u64,
        shard: Option<ShardSpec>,
    ) -> Result<LiveView> {
        let out = match &cfg.target {
            WatchTarget::Stderr => ViewOutput::Stderr { last_lines: 0 },
            WatchTarget::Json(path) => {
                if let Some(dir) = path.parent() {
                    if !dir.as_os_str().is_empty() {
                        std::fs::create_dir_all(dir)?;
                    }
                }
                let fresh = OPENED_LOGS.lock().unwrap().insert(path.clone());
                let mut opts = std::fs::OpenOptions::new();
                opts.create(true).write(true);
                if fresh {
                    // First open this process: start a clean stream.
                    opts.truncate(true);
                } else {
                    // Same process, next experiment (`experiment all`):
                    // share the file; every line is self-describing.
                    opts.append(true);
                }
                let file = opts
                    .open(path)
                    .with_context(|| format!("opening watch log {path:?}"))?;
                ViewOutput::Json {
                    w: std::io::BufWriter::new(file),
                    warned: false,
                }
            }
        };
        Ok(LiveView {
            cfg: cfg.clone(),
            experiment: experiment.to_string(),
            shard: shard.map(|s| s.label()),
            cases_total,
            cases_owned,
            done_cases: BTreeSet::new(),
            latest: BTreeMap::new(),
            out,
        })
    }

    /// The emitter handed to each case's [`CaseWatch`].
    pub fn emitter(view: Arc<Mutex<LiveView>>) -> SnapshotEmitter {
        Arc::new(move |s: &mut Snapshot| {
            // A poisoned lock means another worker panicked mid-render;
            // the run is failing anyway — don't double-panic here.
            if let Ok(mut v) = view.lock() {
                v.emit(s);
            }
        })
    }

    fn emit(&mut self, s: &mut Snapshot) {
        s.seq = SNAPSHOT_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
        if s.done {
            self.done_cases.insert(s.case_index);
        }
        s.cases_done = self.done_cases.len() as u64;
        s.cases_owned = self.cases_owned;
        s.cases_total = self.cases_total;
        if matches!(self.out, ViewOutput::Stderr { .. }) {
            // Only the dashboard renders from per-case state; the JSON
            // path would clone every snapshot into a map nothing reads.
            self.latest.insert(s.case_index, s.clone());
        }
        match &mut self.out {
            ViewOutput::Json { w, warned } => {
                // One line per snapshot, flushed immediately so a
                // concurrent `repro watch` never waits on the buffer.
                // Failures must not kill the sweep, but say so once.
                let r = writeln!(w, "{}", s.to_json().to_string()).and_then(|_| w.flush());
                if let Err(e) = r {
                    if !*warned {
                        *warned = true;
                        eprintln!(
                            "warning: watch log write failed ({e}); \
                             further snapshots of this run may be lost"
                        );
                    }
                }
            }
            ViewOutput::Stderr { last_lines } => {
                let text = render_dashboard(
                    &self.experiment,
                    self.shard.as_deref(),
                    self.done_cases.len() as u64,
                    self.cases_owned,
                    self.latest.values(),
                );
                let lines = text.lines().count();
                // Move up over the previous render and clear it.
                if *last_lines > 0 {
                    eprint!("\x1b[{}A\x1b[J", *last_lines);
                }
                eprint!("{text}");
                *last_lines = lines;
            }
        }
        // Fan the stamped snapshot out to process-wide observers (the
        // serve plane). Taps run while this view is locked — emission
        // order per view stays the tap's delivery order — but outside
        // the registry lock, so a tap can never deadlock registration.
        for tap in snapshot_taps() {
            (*tap)(s);
        }
    }
}

/// Render the in-place dashboard from per-case latest snapshots.
/// Cumulative columns sum over every case; live columns (qps, watts)
/// sum over cases still running; rolling latencies come from the most
/// recently emitted snapshot.
fn render_dashboard<'a>(
    experiment: &str,
    shard: Option<&str>,
    cases_done: u64,
    cases_owned: u64,
    latest: impl Iterator<Item = &'a Snapshot>,
) -> String {
    let mut finished = 0u64;
    let mut energy = 0.0;
    let mut gco2 = 0.0;
    let mut qps = 0.0;
    let mut power = 0.0;
    let mut newest: Option<&Snapshot> = None;
    for s in latest {
        finished += s.finished;
        energy += s.energy_kwh;
        gco2 += s.gco2_g;
        if !s.done {
            qps += s.qps;
            power += s.power_w;
        }
        if newest.map(|n| s.seq > n.seq).unwrap_or(true) {
            newest = Some(s);
        }
    }
    let shard = shard.map(|s| format!(" [shard {s}]")).unwrap_or_default();
    let mut out = format!(
        "⚡ {experiment}{shard}  cases {cases_done}/{cases_owned}  \
         requests {finished}  qps {qps:.2}\n"
    );
    if let Some(n) = newest {
        out.push_str(&format!(
            "   t={:.0}s  ttft p50/p99 {:.3}/{:.3} s  e2e p99 {:.2} s  mfu {:.3}\n",
            n.t_s, n.ttft_p50_s, n.ttft_p99_s, n.e2e_p99_s, n.mfu
        ));
    }
    out.push_str(&format!(
        "   power {power:.0} W  energy {energy:.4} kWh  carbon {gco2:.1} g\n"
    ));
    out
}

/// Open the process-wide watch (if configured) for one experiment run.
/// Returns `None` when watching is off — the zero-overhead default.
pub fn open_view(
    experiment: &str,
    cases_total: u64,
    cases_owned: u64,
    shard: Option<ShardSpec>,
) -> Result<Option<Arc<Mutex<LiveView>>>> {
    match active_watch() {
        None => Ok(None),
        Some(cfg) => Ok(Some(Arc::new(Mutex::new(LiveView::open(
            &cfg,
            experiment,
            cases_total,
            cases_owned,
            shard,
        )?)))),
    }
}

/// Handle a sweep worker uses to attach the watch to one case: the
/// shared view plus the case's global grid index.
#[derive(Clone)]
pub struct CaseTap {
    pub view: Arc<Mutex<LiveView>>,
    pub case_index: u64,
}

impl CaseTap {
    /// Build the case's [`CaseWatch`] (windows + cadence + emitter).
    /// `ci_g_per_kwh` is the accounting carbon intensity used for the
    /// cumulative gCO₂ line.
    pub fn attach(&self, cfg: &SimConfig, ci_g_per_kwh: f64) -> Result<CaseWatch> {
        let (watch_cfg, experiment, shard) = {
            let v = self.view.lock().unwrap();
            (v.cfg.clone(), v.experiment.clone(), v.shard.clone())
        };
        CaseWatch::new(
            cfg,
            watch_cfg.window_s,
            watch_cfg.cadence_s,
            ci_g_per_kwh,
            &experiment,
            shard,
            self.case_index,
            LiveView::emitter(self.view.clone()),
        )
    }
}

/// Run a simulation case through `run`, optionally observed: with a
/// tap, the primary sinks are fanned out to the case's rolling windows
/// ([`FanoutStageSink`]/[`FanoutRequestSink`]) and the final `done`
/// snapshot is emitted after the run; without one, the primaries pass
/// straight through. The one place the watch wiring lives — the grid
/// sweep and the autoscale policy sweep both call this.
///
/// `ci_g_per_kwh` is the accounting carbon intensity for the
/// cumulative gCO₂ snapshot line. The primaries answer `stats()` and
/// keep feeding the accounting, so persisted outputs are byte-
/// identical either way (`tests/watch_observer.rs`).
pub fn run_observed<T>(
    tap: Option<CaseTap>,
    cfg: &SimConfig,
    ci_g_per_kwh: f64,
    sink: &mut dyn StageSink,
    requests: &mut dyn RequestSink,
    run: impl FnOnce(&mut dyn StageSink, &mut dyn RequestSink) -> Result<T>,
) -> Result<T> {
    match tap {
        None => run(sink, requests),
        Some(tap) => {
            let w = tap.attach(cfg, ci_g_per_kwh)?;
            let (mut stage_tap, mut req_tap) = w.taps();
            let mut fan_stage = FanoutStageSink::new(vec![sink, &mut stage_tap]);
            let mut fan_req = FanoutRequestSink::new(vec![requests, &mut req_tap]);
            let out = run(&mut fan_stage, &mut fan_req)?;
            w.finish();
            Ok(out)
        }
    }
}

// ---- `repro watch`: read + aggregate snapshot logs ----------------

/// Resolve the CLI's positional arguments to snapshot files: a file is
/// taken as-is; a directory contributes its own `watch.jsonl` plus any
/// in its immediate subdirectories (the shape of a sweep `--out`
/// tree).
pub fn discover_watch_files(paths: &[PathBuf]) -> Result<Vec<PathBuf>> {
    let mut files = Vec::new();
    for p in paths {
        if p.is_file() {
            files.push(p.clone());
            continue;
        }
        if !p.is_dir() {
            bail!("watch path {p:?} is neither a file nor a directory");
        }
        let own = p.join(WATCH_FILENAME);
        if own.is_file() {
            files.push(own);
        }
        for entry in std::fs::read_dir(p).with_context(|| format!("listing {p:?}"))? {
            let sub = entry?.path().join(WATCH_FILENAME);
            if sub.is_file() {
                files.push(sub);
            }
        }
    }
    files.sort();
    files.dedup();
    Ok(files)
}

/// Read every snapshot line of one JSONL file — [`tail_snapshots`]
/// from a fresh state, so both readers share one parsing and one
/// torn-tail policy: an unterminated final line (a writer mid-append)
/// is skipped with a stderr warning; malformed *complete* lines are
/// real corruption and error out.
pub fn read_snapshots(path: &Path) -> Result<Vec<Snapshot>> {
    let mut state = TailState::default();
    tail_snapshots(path, &mut state)?;
    warn_if_torn_tail(path, &state);
    Ok(state.snapshots)
}

/// Warn when the last read stopped short of the file end — the
/// unparsed bytes are an incomplete final line, and on a *finished*
/// log that line held a case's `done` totals, so the skip must not be
/// silent. Judged from the read itself ([`TailState::torn`]), not a
/// fresh stat, so a live writer appending between read and warn can't
/// fake a torn tail.
pub fn warn_if_torn_tail(path: &Path, state: &TailState) {
    if state.torn {
        eprintln!(
            "warning: {path:?} has an incomplete final line \
             (writer mid-append?); its snapshot was not counted"
        );
    }
}

/// Incremental tail state for one snapshot log: the byte offset of
/// the first unparsed byte plus everything parsed so far. Logs are
/// append-only within one run, so a follower only ever parses the
/// appended suffix — O(new bytes) per refresh instead of re-reading a
/// day-long log in full every tick.
#[derive(Debug, Default)]
pub struct TailState {
    /// First byte not yet parsed (always just past a newline, so the
    /// next read starts line-aligned).
    pub offset: u64,
    /// Snapshots parsed so far, in file order.
    pub snapshots: Vec<Snapshot>,
    /// Whether the last read ended on an incomplete line (bytes past
    /// the final newline **at read time** — re-stating the file later
    /// would race a live writer into false torn-tail warnings).
    pub torn: bool,
    /// Prefix signature: the first [`TAIL_SIG_BYTES`]-or-fewer
    /// *committed* bytes of the file, captured when parsing starts from
    /// byte 0. A truncate-and-rewrite that lands at the same length or
    /// longer keeps `len >= offset` and would otherwise read garbage
    /// mid-line (or silently nothing); comparing the live prefix
    /// against this signature catches the rotation.
    pub sig: Vec<u8>,
    /// How many times this state has re-synced from byte 0 (shrink,
    /// rotation, or parse-error self-heal). Followers that mirror the
    /// snapshot list elsewhere use this to know their copy is stale —
    /// `snapshots.len()` alone can't tell a reset apart from a fresh
    /// run that already re-wrote as many lines.
    pub resets: u64,
}

/// Length cap on [`TailState::sig`]. A snapshot line opens with ~60
/// bytes of constant format tag + experiment id before any
/// run-specific value (seq, sim time, rates) appears, so the cap must
/// reach well past that; 256 bytes covers the volatile fields while
/// keeping the per-poll prefix read O(1). (A rewrite whose first 256
/// committed bytes are byte-identical to the old run's is treated as
/// the same run — and continuing from the old offset is then the
/// correct behaviour for a deterministic re-run writing the same log.)
pub const TAIL_SIG_BYTES: usize = 256;

/// Fold newly appended **complete** lines of `path` into `state`;
/// bytes after the last newline (a writer mid-append) stay unparsed
/// until a later call. A file that was truncated or rotated is a fresh
/// run: the state resets and reparses from byte 0 — and the reset
/// alone counts as a change, so a follower re-renders even before the
/// new run's first line lands. Rotation is detected two ways: the file
/// *shrank* (`len < offset`), or the committed prefix no longer
/// matches the [`TailState::sig`] signature — the latter catches a
/// truncate-and-rewrite that regrew to the same length or longer
/// between polls, which `len` alone can't see and which would
/// otherwise read garbage mid-line. Returns whether anything changed.
/// Malformed complete lines error out *and reset the state*: the next
/// attempt restarts from byte 0 — self-healing for restarts, still
/// loud on every attempt for genuine interior corruption.
pub fn tail_snapshots(path: &Path, state: &mut TailState) -> Result<bool> {
    use std::io::{Read, Seek, SeekFrom};
    let mut f =
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let len = f.metadata()?.len();
    let mut reset = len < state.offset;
    if !reset && state.offset > 0 && !state.sig.is_empty() {
        if len < state.sig.len() as u64 {
            reset = true;
        } else {
            let mut head = vec![0u8; state.sig.len()];
            f.read_exact(&mut head)
                .with_context(|| format!("reading {path:?}"))?;
            reset = head != state.sig;
        }
    }
    if reset {
        let resets = state.resets + 1;
        *state = TailState::default();
        state.resets = resets;
    }
    if len == state.offset {
        state.torn = false;
        return Ok(reset);
    }
    f.seek(SeekFrom::Start(state.offset))?;
    let mut buf = String::new();
    f.take(len - state.offset)
        .read_to_string(&mut buf)
        .with_context(|| format!("reading {path:?}"))?;
    let Some(last_nl) = buf.rfind('\n') else {
        state.torn = true;
        return Ok(reset); // only an incomplete tail so far
    };
    state.torn = last_nl + 1 < buf.len();
    // Stage, then commit: on success a retrying follower never
    // double-counts; on failure the whole state resets (see above).
    let mut fresh = Vec::new();
    for line in buf[..last_nl].lines().filter(|l| !l.trim().is_empty()) {
        let parsed = crate::util::json::parse(line)
            .map_err(|e| anyhow::anyhow!("{e}"))
            .and_then(|v| Snapshot::from_json(&v))
            .with_context(|| format!("{path:?} past byte {}", state.offset));
        match parsed {
            Ok(s) => fresh.push(s),
            Err(e) => {
                let resets = state.resets + 1;
                *state = TailState::default();
                state.resets = resets;
                return Err(e);
            }
        }
    }
    let changed = reset || !fresh.is_empty();
    state.snapshots.extend(fresh);
    if state.offset == 0 {
        // First committed bytes of this incarnation of the file:
        // capture the rotation-detection signature.
        let committed = &buf.as_bytes()[..last_nl + 1];
        state.sig = committed[..committed.len().min(TAIL_SIG_BYTES)].to_vec();
    }
    state.offset += last_nl as u64 + 1;
    Ok(changed)
}

/// Whether `new` supersedes `old` as the latest state of one
/// (experiment, shard, case) slot. Files replay in write order; `seq`
/// orders within one file, `t_s`/`done` break ties across files of the
/// same shard. `>=` (not `>`): an equal-keyed replay refreshes the
/// slot, which keeps "last seen wins" for byte-identical re-reads.
pub fn snapshot_supersedes(new: &Snapshot, old: &Snapshot) -> bool {
    (new.done, new.t_s, new.seq) >= (old.done, old.t_s, old.seq)
}

/// One experiment's aggregate over every shard's snapshots.
#[derive(Debug, Clone)]
pub struct ExpAggregate {
    pub experiment: String,
    /// Shard labels seen (empty-string key for unsharded snapshots).
    pub shards: BTreeSet<String>,
    pub cases_total: u64,
    /// Cases whose latest snapshot is `done`.
    pub cases_done: u64,
    /// Σ over per-case latest snapshots (cumulative fields).
    pub finished: u64,
    pub stages: u64,
    pub energy_kwh: f64,
    pub gco2_g: f64,
    /// Σ windowed rates over cases still running.
    pub qps: f64,
    pub power_w: f64,
    /// Furthest case sim time seen.
    pub max_t_s: f64,
    /// Rolling latencies of the most recent snapshot.
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub e2e_p99_s: f64,
}

impl ExpAggregate {
    /// JSON shape served by `GET /v1/fleet` — field names mirror the
    /// struct (shards as a sorted array).
    pub fn to_json(&self) -> crate::util::json::Value {
        let mut v = crate::util::json::Value::obj();
        v.set("experiment", self.experiment.as_str())
            .set(
                "shards",
                crate::util::json::Value::Arr(
                    self.shards
                        .iter()
                        .map(|s| crate::util::json::Value::Str(s.clone()))
                        .collect(),
                ),
            )
            .set("cases_total", self.cases_total)
            .set("cases_done", self.cases_done)
            .set("finished", self.finished)
            .set("stages", self.stages)
            .set("energy_kwh", self.energy_kwh)
            .set("gco2_g", self.gco2_g)
            .set("qps", self.qps)
            .set("power_w", self.power_w)
            .set("max_t_s", self.max_t_s)
            .set("ttft_p50_s", self.ttft_p50_s)
            .set("ttft_p99_s", self.ttft_p99_s)
            .set("e2e_p99_s", self.e2e_p99_s);
        v
    }
}

/// Fold snapshots (from any number of shard files, in any order) into
/// per-experiment aggregates. Within one experiment the latest
/// snapshot per (shard, case) wins — shards own disjoint global case
/// indices, so summing latest snapshots reproduces sweep totals.
/// Takes borrows so a tailing caller can aggregate its cache without
/// cloning thousands of accumulated snapshots per refresh.
pub fn aggregate<'a>(snaps: impl IntoIterator<Item = &'a Snapshot>) -> Vec<ExpAggregate> {
    // (experiment, shard label, case) -> latest snapshot. Keys borrow
    // from the snapshots — a follower re-aggregating a long history
    // every refresh must not pay two String clones per snapshot.
    let mut latest: BTreeMap<(&str, &str, u64), &Snapshot> = BTreeMap::new();
    for s in snaps {
        let key = (
            s.experiment.as_str(),
            s.shard.as_deref().unwrap_or(""),
            s.case_index,
        );
        let slot = latest.entry(key).or_insert(s);
        if snapshot_supersedes(s, slot) {
            *slot = s;
        }
    }
    let mut by_exp: BTreeMap<String, ExpAggregate> = BTreeMap::new();
    let mut newest: BTreeMap<String, (f64, u64)> = BTreeMap::new();
    for ((exp, shard_label, _), s) in &latest {
        let agg = by_exp.entry(exp.to_string()).or_insert_with(|| ExpAggregate {
            experiment: exp.to_string(),
            shards: BTreeSet::new(),
            cases_total: 0,
            cases_done: 0,
            finished: 0,
            stages: 0,
            energy_kwh: 0.0,
            gco2_g: 0.0,
            qps: 0.0,
            power_w: 0.0,
            max_t_s: 0.0,
            ttft_p50_s: 0.0,
            ttft_p99_s: 0.0,
            e2e_p99_s: 0.0,
        });
        if !shard_label.is_empty() {
            agg.shards.insert(shard_label.to_string());
        }
        agg.cases_total = agg.cases_total.max(s.cases_total);
        agg.cases_done += s.done as u64;
        agg.finished += s.finished;
        agg.stages += s.stages;
        agg.energy_kwh += s.energy_kwh;
        agg.gco2_g += s.gco2_g;
        if !s.done {
            agg.qps += s.qps;
            agg.power_w += s.power_w;
        }
        agg.max_t_s = agg.max_t_s.max(s.t_s);
        let key = (s.t_s, s.seq);
        if newest.get(*exp).map(|&n| key >= n).unwrap_or(true) {
            newest.insert(exp.to_string(), key);
            agg.ttft_p50_s = s.ttft_p50_s;
            agg.ttft_p99_s = s.ttft_p99_s;
            agg.e2e_p99_s = s.e2e_p99_s;
        }
    }
    by_exp.into_values().collect()
}

/// Render the `repro watch` dashboard for the aggregates.
pub fn render_watch(aggs: &[ExpAggregate], files: usize) -> String {
    let mut out = format!("repro watch — {files} snapshot file(s)\n");
    for a in aggs {
        let shard = if a.shards.is_empty() {
            String::new()
        } else {
            format!(
                " [{} shard(s): {}]",
                a.shards.len(),
                a.shards.iter().cloned().collect::<Vec<_>>().join(", ")
            )
        };
        out.push_str(&format!(
            "\n⚡ {}{}  cases {}/{}  t={:.0}s\n",
            a.experiment, shard, a.cases_done, a.cases_total, a.max_t_s
        ));
        out.push_str(&format!(
            "   requests {}  qps {:.2}  ttft p50/p99 {:.3}/{:.3} s  e2e p99 {:.2} s\n",
            a.finished, a.qps, a.ttft_p50_s, a.ttft_p99_s, a.e2e_p99_s
        ));
        out.push_str(&format!(
            "   power {:.0} W  energy {:.4} kWh  carbon {:.1} g  ({} stages)\n",
            a.power_w, a.energy_kwh, a.gco2_g, a.stages
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(exp: &str, shard: Option<&str>, case: u64, seq: u64, t: f64, done: bool) -> Snapshot {
        Snapshot {
            experiment: exp.to_string(),
            shard: shard.map(|s| s.to_string()),
            case_index: case,
            seq,
            t_s: t,
            done,
            cases_done: 0,
            cases_owned: 4,
            cases_total: 4,
            finished: 100 + case,
            stages: 10 * (case + 1),
            qps: 2.0,
            ttft_p50_s: 0.4,
            ttft_p99_s: 1.9,
            e2e_p50_s: 3.0,
            e2e_p99_s: 9.0,
            norm_latency_p50_s_per_tok: 0.2,
            power_w: 500.0,
            mfu: 0.3,
            energy_kwh: 0.5,
            gco2_g: 200.0,
        }
    }

    #[test]
    fn watch_config_parses_targets() {
        assert_eq!(WatchConfig::parse("stderr").unwrap().target, WatchTarget::Stderr);
        assert_eq!(
            WatchConfig::parse("json:out/w.jsonl").unwrap().target,
            WatchTarget::Json(PathBuf::from("out/w.jsonl"))
        );
        assert!(WatchConfig::parse("json:").is_err());
        assert!(WatchConfig::parse("tcp:1234").is_err());
    }

    #[test]
    fn watch_global_roundtrips() {
        let _guard = WATCH_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_watch(None);
        assert_eq!(active_watch(), None);
        set_watch(Some(WatchConfig::stderr()));
        assert_eq!(active_watch(), Some(WatchConfig::stderr()));
        set_watch(None);
        assert_eq!(active_watch(), None);
    }

    /// The slot-ordering rule the aggregator and the serve fleet map
    /// share: done beats running, then sim time, then seq; equal keys
    /// refresh (last seen wins).
    #[test]
    fn snapshot_supersedes_orders_done_then_time_then_seq() {
        let running = snap("expX", None, 0, 5, 100.0, false);
        let done = snap("expX", None, 0, 2, 50.0, true);
        assert!(snapshot_supersedes(&done, &running));
        assert!(!snapshot_supersedes(&running, &done));
        let later = snap("expX", None, 0, 1, 200.0, false);
        assert!(snapshot_supersedes(&later, &running));
        let newer_seq = snap("expX", None, 0, 6, 100.0, false);
        assert!(snapshot_supersedes(&newer_seq, &running));
        // Equal keys refresh the slot.
        assert!(snapshot_supersedes(&running, &running.clone()));
    }

    /// Registered taps observe every stamped snapshot a view emits;
    /// removal stops delivery.
    #[test]
    fn snapshot_taps_observe_stamped_snapshots() {
        let got: Arc<Mutex<Vec<Snapshot>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = got.clone();
        let id = add_snapshot_tap(Arc::new(move |s: &Snapshot| {
            sink.lock().unwrap().push(s.clone());
        }));
        let dir = std::env::temp_dir().join("vidur_energy_live_tap");
        std::fs::remove_dir_all(&dir).ok();
        let cfg = WatchConfig {
            target: WatchTarget::Json(dir.join("w.jsonl")),
            cadence_s: 60.0,
            window_s: 300.0,
        };
        let view = Arc::new(Mutex::new(LiveView::open(&cfg, "expT", 1, 1, None).unwrap()));
        let emit = LiveView::emitter(view.clone());
        let mut s = snap("expT", None, 0, 0, 60.0, true);
        (*emit)(&mut s);
        {
            let seen = got.lock().unwrap();
            // Other tests emit concurrently through the same global
            // registry — find our own snapshot rather than asserting
            // an exclusive stream.
            let ours: Vec<_> = seen.iter().filter(|x| x.experiment == "expT").collect();
            assert_eq!(ours.len(), 1);
            // The tap saw the *stamped* snapshot.
            assert!(ours[0].seq > 0);
            assert_eq!(ours[0].cases_done, 1);
        }
        remove_snapshot_tap(id);
        let mut s2 = snap("expT", None, 0, 0, 120.0, true);
        (*emit)(&mut s2);
        let seen = got.lock().unwrap();
        assert_eq!(
            seen.iter().filter(|x| x.experiment == "expT").count(),
            1,
            "removed tap must not receive further snapshots"
        );
        drop(seen);
        drop(view);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// ExpAggregate::to_json mirrors the struct fields.
    #[test]
    fn exp_aggregate_serializes_fields() {
        let aggs = aggregate(&[
            snap("expX", Some("0/2"), 0, 1, 60.0, true),
            snap("expX", Some("1/2"), 1, 2, 90.0, false),
        ]);
        let v = aggs[0].to_json();
        assert_eq!(v.req_str("experiment").unwrap(), "expX");
        assert_eq!(v.req_u64("cases_done").unwrap(), 1);
        assert_eq!(v.req_u64("finished").unwrap(), 100 + 101);
        let shards = match v.get("shards") {
            Some(crate::util::json::Value::Arr(a)) => a.len(),
            other => panic!("bad shards field: {other:?}"),
        };
        assert_eq!(shards, 2);
        assert!((v.req_f64("max_t_s").unwrap() - 90.0).abs() < 1e-12);
        // Round-trips through the parser.
        let text = v.to_string();
        crate::util::json::parse(&text).unwrap();
    }

    /// Aggregation across two shard files: latest-per-case wins,
    /// cumulative fields sum, live rates only count running cases.
    #[test]
    fn aggregate_sums_latest_per_case_across_shards() {
        let snaps = vec![
            // shard 0/2 owns cases 0 and 2; case 0 has an older
            // snapshot that must lose to its final one.
            snap("expX", Some("0/2"), 0, 1, 60.0, false),
            snap("expX", Some("0/2"), 0, 2, 120.0, true),
            snap("expX", Some("0/2"), 2, 3, 90.0, false),
            // shard 1/2 owns cases 1 and 3.
            snap("expX", Some("1/2"), 1, 1, 150.0, true),
            snap("expX", Some("1/2"), 3, 2, 30.0, false),
            // An unrelated experiment aggregates separately.
            snap("other", None, 0, 1, 10.0, true),
        ];
        let aggs = aggregate(&snaps);
        assert_eq!(aggs.len(), 2);
        let x = aggs.iter().find(|a| a.experiment == "expX").unwrap();
        assert_eq!(x.cases_total, 4);
        assert_eq!(x.cases_done, 2); // cases 0 and 1
        assert_eq!(x.shards.len(), 2);
        // finished sums the latest snapshot of each of the 4 cases.
        assert_eq!(x.finished, (100) + (101) + (102) + (103));
        assert_eq!(x.stages, 10 + 20 + 30 + 40);
        assert!((x.energy_kwh - 2.0).abs() < 1e-12);
        // Live rates: only the two running cases contribute.
        assert!((x.qps - 4.0).abs() < 1e-12);
        assert!((x.power_w - 1000.0).abs() < 1e-12);
        assert_eq!(x.max_t_s, 150.0);
        let other = aggs.iter().find(|a| a.experiment == "other").unwrap();
        assert_eq!(other.cases_done, 1);
        assert!(other.shards.is_empty());
        // Rendering mentions both experiments.
        let text = render_watch(&aggs, 2);
        assert!(text.contains("expX") && text.contains("other"), "{text}");
    }

    /// JSONL reading: well-formed lines parse; a torn final line is
    /// tolerated (live tail), interior corruption is an error.
    #[test]
    fn read_snapshots_tolerates_torn_tail_only() {
        let dir = std::env::temp_dir().join("vidur_energy_live_read");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(WATCH_FILENAME);
        let a = snap("expX", None, 0, 1, 60.0, false).to_json().to_string();
        let b = snap("expX", None, 0, 2, 120.0, true).to_json().to_string();
        std::fs::write(&p, format!("{a}\n{b}\n{{\"format\":\"vidur")).unwrap();
        let snaps = read_snapshots(&p).unwrap();
        assert_eq!(snaps.len(), 2);
        assert!(snaps[1].done);
        // Interior corruption is not silently skipped.
        std::fs::write(&p, format!("{a}\nnot json\n{b}\n")).unwrap();
        assert!(read_snapshots(&p).is_err());
        // Discovery: the file directly, the dir, and a parent of
        // shard dirs all resolve to the same file.
        std::fs::write(&p, format!("{a}\n")).unwrap();
        let direct = discover_watch_files(&[p.clone()]).unwrap();
        let via_dir = discover_watch_files(&[dir.clone()]).unwrap();
        assert_eq!(direct, via_dir);
        let sub = dir.join("shard0");
        std::fs::create_dir_all(&sub).unwrap();
        std::fs::write(sub.join(WATCH_FILENAME), format!("{b}\n")).unwrap();
        let both = discover_watch_files(&[dir.clone()]).unwrap();
        assert_eq!(both.len(), 2);
        assert!(discover_watch_files(&[dir.join("nope")]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The follower's incremental reader: only appended complete lines
    /// are parsed (a torn tail waits for its remainder), quiet ticks
    /// report no change, and a shrunken file (fresh run truncated the
    /// log) resets the state.
    #[test]
    fn tail_snapshots_parses_appended_suffix_and_resets_on_truncate() {
        use std::io::Write as _;
        let dir = std::env::temp_dir().join("vidur_energy_live_tail");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.jsonl");
        let a = snap("expX", None, 0, 1, 60.0, false).to_json().to_string();
        let b = snap("expX", None, 0, 2, 120.0, false).to_json().to_string();
        let c = snap("expX", None, 0, 3, 180.0, true).to_json().to_string();

        std::fs::write(&p, format!("{a}\n")).unwrap();
        let mut st = TailState::default();
        assert!(tail_snapshots(&p, &mut st).unwrap());
        assert_eq!(st.snapshots.len(), 1);

        // Append one complete line plus the torn start of another.
        let append = |text: &str| {
            let mut f = std::fs::OpenOptions::new().append(true).open(&p).unwrap();
            write!(f, "{text}").unwrap();
        };
        append(&format!("{b}\n"));
        append(&c[..10]);
        assert!(tail_snapshots(&p, &mut st).unwrap());
        assert_eq!(st.snapshots.len(), 2, "torn tail must wait");
        assert!(st.torn, "read-time torn flag must be set");
        // Quiet tick: nothing new.
        assert!(!tail_snapshots(&p, &mut st).unwrap());
        // The remainder arrives; the line completes.
        append(&format!("{}\n", &c[10..]));
        assert!(tail_snapshots(&p, &mut st).unwrap());
        assert_eq!(st.snapshots.len(), 3);
        assert!(st.snapshots[2].done);
        assert!(!st.torn);

        // A shorter rewrite is a fresh run: state resets and reparses.
        std::fs::write(&p, format!("{a}\n")).unwrap();
        assert!(tail_snapshots(&p, &mut st).unwrap());
        assert_eq!(st.snapshots.len(), 1);
        assert_eq!(st.snapshots[0], snap("expX", None, 0, 1, 60.0, false));

        // Reset to a still-empty file is itself a change (the follower
        // must drop the stale render), with nothing parsed yet.
        std::fs::write(&p, "").unwrap();
        assert!(tail_snapshots(&p, &mut st).unwrap());
        assert!(st.snapshots.is_empty());
        assert!(!tail_snapshots(&p, &mut st).unwrap());

        // A log truncated and regrown *past* the old offset between
        // polls used to parse misaligned mid-line; the prefix signature
        // now catches the rotation and reparses cleanly from byte 0.
        std::fs::write(&p, format!("{a}\n")).unwrap();
        assert!(tail_snapshots(&p, &mut st).unwrap());
        let long = snap("expX-much-longer-name", None, 7, 9, 240.0, true)
            .to_json()
            .to_string();
        assert!(
            long.len() > a.len() + 1,
            "regrown first line must strictly span the old offset"
        );
        std::fs::write(&p, format!("{long}\n{long}\n")).unwrap();
        let before = st.resets;
        assert!(tail_snapshots(&p, &mut st).unwrap(), "rotation is a change");
        assert_eq!(st.resets, before + 1, "rotation must count as a reset");
        assert_eq!(st.snapshots.len(), 2);
        assert_eq!(st.snapshots[1].case_index, 7);

        // Genuine interior corruption (a malformed *complete* line
        // appended to an otherwise-healthy log) still errors loudly and
        // resets, so a later repair reparses from the start.
        append("not json at all\n");
        assert!(tail_snapshots(&p, &mut st).is_err(), "corrupt line must error");
        assert_eq!(st.offset, 0, "error must reset the state");
        std::fs::write(&p, format!("{a}\n")).unwrap();
        assert!(tail_snapshots(&p, &mut st).unwrap());
        assert_eq!(st.snapshots.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Regression (truncate mid-follow): a rewrite that lands at the
    /// *same length or longer* keeps `len >= offset`, so the old
    /// shrink-only check missed it — the follower either went silently
    /// stale (same length) or mixed lines of two different runs
    /// (newline-aligned longer rewrite). The prefix signature must
    /// catch both and re-sync from byte 0.
    #[test]
    fn tail_snapshots_resyncs_on_same_length_and_longer_rewrites() {
        let dir = std::env::temp_dir().join("vidur_energy_live_tail_rewrite");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.jsonl");
        let x = snap("expX", None, 0, 1, 60.0, false).to_json().to_string();
        let y = snap("expX", None, 0, 2, 90.0, false).to_json().to_string();
        let z = snap("expX", None, 0, 3, 120.0, true).to_json().to_string();
        // Preconditions that make these the hard cases: equal length
        // (so `len` can't flag the first rewrite, and the old offset is
        // newline-aligned in the second), differing inside the
        // signature window.
        assert_eq!(x.len(), y.len(), "test needs a same-length rewrite");
        let w = x.len().min(TAIL_SIG_BYTES);
        assert_ne!(
            x.as_bytes()[..w],
            y.as_bytes()[..w],
            "rewrite must differ inside the signature window"
        );

        // Same-length rewrite: x → y. Without the signature this read
        // reported "no change" and left the stale x cached forever.
        std::fs::write(&p, format!("{x}\n")).unwrap();
        let mut st = TailState::default();
        assert!(tail_snapshots(&p, &mut st).unwrap());
        assert_eq!(st.snapshots.len(), 1);
        assert_eq!(st.resets, 0);
        std::fs::write(&p, format!("{y}\n")).unwrap();
        assert!(tail_snapshots(&p, &mut st).unwrap(), "rewrite must be a change");
        assert_eq!(st.resets, 1);
        assert_eq!(st.snapshots.len(), 1);
        assert_eq!(st.snapshots[0].seq, 2, "must hold the new run's line, not the old");

        // Newline-aligned longer rewrite: y → y'|z where the first new
        // line has y's exact length. Without the signature the old
        // offset landed exactly on the second line's start and the
        // reader produced the garbage mix [old, z] instead of [new, z].
        std::fs::write(&p, format!("{x}\n{z}\n")).unwrap();
        assert!(tail_snapshots(&p, &mut st).unwrap());
        assert_eq!(st.resets, 2);
        assert_eq!(st.snapshots.len(), 2);
        assert_eq!(st.snapshots[0].seq, 1, "first line re-read from byte 0");
        assert!(st.snapshots[1].done);

        // After a re-sync, appends keep tailing incrementally.
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&p).unwrap();
        writeln!(f, "{y}").unwrap();
        drop(f);
        assert!(tail_snapshots(&p, &mut st).unwrap());
        assert_eq!(st.snapshots.len(), 3);
        assert_eq!(st.resets, 2, "plain append is not a reset");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// End-to-end through a JSON-target LiveView: snapshots get
    /// stamped with monotone seq and case progress, and the file
    /// round-trips through the reader.
    #[test]
    fn live_view_stamps_and_appends_jsonl() {
        let dir = std::env::temp_dir().join("vidur_energy_live_view");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("w.jsonl");
        let cfg = WatchConfig {
            target: WatchTarget::Json(path.clone()),
            cadence_s: 60.0,
            window_s: 300.0,
        };
        let view = Arc::new(Mutex::new(
            LiveView::open(&cfg, "expX", 2, 1, Some(ShardSpec::new(1, 2).unwrap())).unwrap(),
        ));
        let emit = LiveView::emitter(view.clone());
        let mut s1 = snap("expX", Some("1/2"), 1, 0, 60.0, false);
        let mut s2 = snap("expX", Some("1/2"), 1, 0, 120.0, true);
        (*emit)(&mut s1);
        (*emit)(&mut s2);
        // seq is a process-wide counter (other tests may have bumped
        // it): only the strict ordering is guaranteed.
        assert!(s2.seq > s1.seq);
        assert_eq!(s1.cases_done, 0);
        assert_eq!(s2.cases_done, 1);
        assert_eq!(s2.cases_total, 2);
        drop(view);
        let back = read_snapshots(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0], s1);
        assert_eq!(back[1], s2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
