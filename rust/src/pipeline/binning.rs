//! Eq. 5: fold variable-duration batch-stage power samples into
//! fixed-width bins,
//!
//!   P̄_b = Σᵢ Pᵢ·Δtᵢ / Σᵢ Δtᵢ   over samples i in bin b,
//!
//! then fill the time not covered by any stage with idle power so the
//! resulting load profile is physically complete (GPUs draw `p_idle`
//! between stages).
//!
//! Backends: native rust accumulation, or the AOT binning kernel
//! (`artifacts/bin_power.hlo.txt`) executed in (4096-sample, 512-bin)
//! windows via PJRT — parity-tested against native.

use crate::autoscale::FleetTimeline;
use crate::config::simconfig::SimConfig;
use crate::runtime::{artifacts, pjrt::cached_executable};
use crate::telemetry::{StageLog, StageRecord};
use anyhow::Result;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinningBackend {
    Native,
    Hlo,
}

/// Binned whole-cluster power profile.
#[derive(Debug, Clone)]
pub struct BinnedProfile {
    /// Bin width, seconds.
    pub interval_s: f64,
    /// Average cluster power per bin, W.
    pub power_w: Vec<f64>,
    /// Stage-covered seconds per bin (diagnostics).
    pub covered_s: Vec<f64>,
}

impl BinnedProfile {
    pub fn total_energy_kwh(&self) -> f64 {
        self.power_w.iter().sum::<f64>() * self.interval_s / 3.6e6
    }
    pub fn len(&self) -> usize {
        self.power_w.len()
    }
    pub fn is_empty(&self) -> bool {
        self.power_w.is_empty()
    }
}

/// Online Eq. 5 accumulator: folds stage records into fixed-width
/// (energy, covered-time) bins as they are produced, holding O(bins)
/// state instead of the full stage vector. Both the native backend of
/// [`bin_stages_fleet`] and the streaming
/// [`crate::telemetry::StreamingSink`] run on this type, so on
/// engine-produced logs — where every stage starts strictly before
/// the horizon — the two paths are the same code and agree
/// bit-for-bit.
#[derive(Debug, Clone)]
pub struct BinAccumulator {
    interval_s: f64,
    p_idle: f64,
    energy: Vec<f64>,
    covered: Vec<f64>,
}

impl BinAccumulator {
    pub fn new(interval_s: f64, p_idle: f64) -> Self {
        BinAccumulator {
            interval_s,
            p_idle,
            energy: Vec::new(),
            covered: Vec::new(),
        }
    }

    pub fn interval_s(&self) -> f64 {
        self.interval_s
    }

    /// Bins touched so far — the sink's peak resident state (the vec
    /// only ever grows, so `len` == peak).
    pub fn len(&self) -> usize {
        self.energy.len()
    }

    /// Fold one stage sample into the bin containing its start
    /// timestamp (the paper timestamps each batch stage with Vidur's
    /// internal clock).
    pub fn add(&mut self, r: &StageRecord) {
        let b = (r.start_s / self.interval_s) as usize;
        if b >= self.energy.len() {
            self.energy.resize(b + 1, 0.0);
            self.covered.resize(b + 1, 0.0);
        }
        self.energy[b] += r.replica_power_w(self.p_idle) * r.dt_s;
        self.covered[b] += r.dt_s;
    }

    /// Finish against a fleet timeline: clamp to the horizon's bin
    /// count and fill uncovered live GPU-time with idle power.
    ///
    /// Records starting past the horizon (possible only in synthetic
    /// logs; the engine never emits one) fold into the last bin. That
    /// lands them where the per-record `min(n_bins-1)` clamp would,
    /// but as a bin-order fold rather than a record-order add — so on
    /// such logs the last bin can differ from the materialized path
    /// by float-association ulps. In-horizon records are bit-exact.
    pub fn finish(&self, cfg: &SimConfig, fleet: &FleetTimeline) -> Result<BinnedProfile> {
        let n_bins = ((fleet.horizon_s / self.interval_s).ceil() as usize).max(1);
        let mut energy = self.energy.clone();
        let mut covered = self.covered.clone();
        if energy.len() > n_bins {
            for b in n_bins..energy.len() {
                energy[n_bins - 1] += energy[b];
                covered[n_bins - 1] += covered[b];
            }
        }
        energy.resize(n_bins, 0.0);
        covered.resize(n_bins, 0.0);
        idle_fill(cfg, fleet, self.interval_s, self.p_idle, energy, covered)
    }
}

/// Shared Eq. 5 tail: idle-fill live GPU-time not covered by stages
/// and convert per-bin energy to average power. `energy`/`covered`
/// must already have exactly the horizon's bin count. `p_idle` is the
/// same idle wattage the stages were accumulated under — callers with
/// an overridden power model (e.g. an idle-free accounting model) get
/// a profile coherent with that model rather than the hardware spec.
fn idle_fill(
    cfg: &SimConfig,
    fleet: &FleetTimeline,
    interval_s: f64,
    p_idle: f64,
    energy: Vec<f64>,
    covered: Vec<f64>,
) -> Result<BinnedProfile> {
    let horizon_s = fleet.horizon_s;
    let gpus_per_replica = cfg.gpus_per_replica() as f64;
    let n_bins = energy.len();

    // The final bin only exists up to the horizon, not its full width,
    // and bins where replicas were drained contain proportionally less
    // idle time.
    let mut power_w = Vec::with_capacity(n_bins);
    for b in 0..n_bins {
        let lo = b as f64 * interval_s;
        let hi = (lo + interval_s).min(horizon_s);
        let live_gpu_s = fleet.live_seconds_in(lo, hi) * gpus_per_replica;
        let covered_gpu_s = covered[b] * gpus_per_replica;
        let idle_gpu_s = (live_gpu_s - covered_gpu_s).max(0.0);
        let joules = energy[b] + idle_gpu_s * p_idle;
        power_w.push(joules / interval_s);
    }
    Ok(BinnedProfile {
        interval_s,
        power_w,
        covered_s: covered,
    })
}

/// Bin a stage log into `interval_s` windows. Samples are assigned to
/// the bin containing their start timestamp (the paper's pipeline
/// timestamps each batch stage with Vidur's internal clock).
///
/// Fixed-fleet convenience over [`bin_stages_fleet`]: all
/// `cfg.replicas` replicas exist for the whole makespan.
pub fn bin_stages(
    cfg: &SimConfig,
    log: &StageLog,
    makespan_s: f64,
    interval_s: f64,
    backend: BinningBackend,
) -> Result<BinnedProfile> {
    bin_stages_fleet(
        cfg,
        log,
        &FleetTimeline::static_fleet(cfg.replicas, makespan_s),
        interval_s,
        backend,
    )
}

/// Fleet-aware Eq. 5 binning (DESIGN.md §6): stage samples are folded
/// into fixed-width bins exactly as [`bin_stages`], but the idle fill
/// per bin covers only GPU-time of replicas that exist during that bin
/// (per the [`FleetTimeline`]). The resulting profile is the
/// **time-varying demand signal** the co-simulation consumes, so the
/// microgrid/battery/controllers see autoscaling effects.
pub fn bin_stages_fleet(
    cfg: &SimConfig,
    log: &StageLog,
    fleet: &FleetTimeline,
    interval_s: f64,
    backend: BinningBackend,
) -> Result<BinnedProfile> {
    anyhow::ensure!(interval_s > 0.0, "interval must be positive");
    let n_bins = ((fleet.horizon_s / interval_s).ceil() as usize).max(1);
    let p_idle = cfg.gpu_spec()?.p_idle;

    match backend {
        BinningBackend::Native => {
            let mut acc = BinAccumulator::new(interval_s, p_idle);
            for r in &log.records {
                acc.add(r);
            }
            acc.finish(cfg, fleet)
        }
        BinningBackend::Hlo => {
            let (energy, covered) = bin_hlo(log, p_idle, interval_s, n_bins)?;
            idle_fill(cfg, fleet, interval_s, p_idle, energy, covered)
        }
    }
}

/// HLO-kernel accumulation in (N_SAMPLES, N_BINS) windows.
fn bin_hlo(
    log: &StageLog,
    p_idle: f64,
    interval_s: f64,
    n_bins: usize,
) -> Result<(Vec<f64>, Vec<f64>)> {
    let exe = cached_executable("bin_power")?;
    let n_chunk = artifacts::N_SAMPLES;
    let b_chunk = artifacts::N_BINS;

    let mut energy = vec![0.0f64; n_bins];
    let mut covered = vec![0.0f64; n_bins];

    // Sort sample indices by bin so each kernel window spans < 512 bins.
    let mut order: Vec<usize> = (0..log.records.len()).collect();
    order.sort_by_key(|&i| (log.records[i].start_s / interval_s) as usize);

    let mut i = 0usize;
    let mut p_buf = vec![0f32; n_chunk];
    let mut dt_buf = vec![0f32; n_chunk];
    let mut idx_buf = vec![0f32; n_chunk];
    while i < order.len() {
        let base_bin = (log.records[order[i]].start_s / interval_s) as usize;
        let mut n = 0usize;
        while n < n_chunk && i + n < order.len() {
            let r = &log.records[order[i + n]];
            let b = ((r.start_s / interval_s) as usize).min(n_bins - 1);
            if b >= base_bin + b_chunk {
                break; // next window
            }
            p_buf[n] = r.replica_power_w(p_idle) as f32;
            dt_buf[n] = r.dt_s as f32;
            idx_buf[n] = (b - base_bin) as f32;
            n += 1;
        }
        // Pad the tail with zero-duration samples in bin 0.
        for k in n..n_chunk {
            p_buf[k] = 0.0;
            dt_buf[k] = 0.0;
            idx_buf[k] = 0.0;
        }
        let out = exe.call_f32(&[&p_buf, &dt_buf, &idx_buf])?;
        anyhow::ensure!(out.len() == 2, "bin kernel returned {} outputs", out.len());
        for (k, (&e, &w)) in out[0].iter().zip(out[1].iter()).enumerate() {
            let b = base_bin + k;
            if b < n_bins {
                energy[b] += e as f64;
                covered[b] += w as f64;
            }
        }
        i += n;
    }
    Ok((energy, covered))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::replica::StageKind;
    use crate::telemetry::StageRecord;

    fn log_with(stages: &[(f64, f64, f64)]) -> StageLog {
        // (start, dt, power)
        let mut log = StageLog::new();
        for &(start, dt, p) in stages {
            log.push(StageRecord {
                replica: 0,
                pp_stage: 0,
                start_s: start,
                dt_s: dt,
                batch_size: 1,
                new_tokens: 1,
                mfu: 0.1,
                power_w: p,
                active_gpus: 1,
                idle_gpus: 0,
                flops: 1.0,
                kind: StageKind::Decode,
            });
        }
        log
    }

    #[test]
    fn idle_only_bins_at_idle_power() {
        let cfg = SimConfig::default();
        let log = StageLog::new();
        let prof = bin_stages(&cfg, &log, 120.0, 60.0, BinningBackend::Native).unwrap();
        assert_eq!(prof.len(), 2);
        for p in &prof.power_w {
            assert!((p - 100.0).abs() < 1e-9); // 1 GPU idle
        }
    }

    #[test]
    fn eq5_weighted_average() {
        let cfg = SimConfig::default();
        // Bin 0: 30 s at 400 W + 30 s uncovered at idle 100 W -> 250 W.
        let log = log_with(&[(0.0, 30.0, 400.0)]);
        let prof = bin_stages(&cfg, &log, 60.0, 60.0, BinningBackend::Native).unwrap();
        assert!((prof.power_w[0] - 250.0).abs() < 1e-9, "{}", prof.power_w[0]);
    }

    #[test]
    fn energy_conserved_across_binning() {
        let cfg = SimConfig::default();
        let stages: Vec<(f64, f64, f64)> = (0..100)
            .map(|i| (i as f64 * 0.7, 0.5, 150.0 + (i % 7) as f64 * 30.0))
            .collect();
        let log = log_with(&stages);
        let makespan = 80.0;
        let prof = bin_stages(&cfg, &log, makespan, 10.0, BinningBackend::Native).unwrap();
        // Total = stage energy + idle fill.
        let stage_j: f64 = stages.iter().map(|&(_, dt, p)| dt * p).sum();
        let covered: f64 = stages.iter().map(|&(_, dt, _)| dt).sum();
        let idle_j = (makespan - covered) * 100.0;
        let total_j: f64 = prof.power_w.iter().sum::<f64>() * 10.0;
        assert!(
            (total_j - (stage_j + idle_j)).abs() / total_j < 1e-9,
            "binned {total_j} vs direct {}",
            stage_j + idle_j
        );
    }

    #[test]
    fn fleet_binning_shrinks_idle_fill_with_the_fleet() {
        let cfg = SimConfig::default();
        let log = StageLog::new();
        // Two replicas for the first minute, one for the second.
        let mut fleet = FleetTimeline::new();
        fleet.provision(0, 0.0);
        fleet.online(0, 0.0);
        fleet.provision(1, 0.0);
        fleet.online(1, 0.0);
        fleet.drain_start(1, 60.0);
        fleet.offline(1, 60.0);
        fleet.close(120.0);
        let prof =
            bin_stages_fleet(&cfg, &log, &fleet, 60.0, BinningBackend::Native).unwrap();
        assert_eq!(prof.len(), 2);
        assert!((prof.power_w[0] - 200.0).abs() < 1e-9); // 2 idle GPUs
        assert!((prof.power_w[1] - 100.0).abs() < 1e-9); // 1 idle GPU
    }

    #[test]
    fn fleet_binning_conserves_energy_with_partial_bins() {
        let cfg = SimConfig::default();
        // One replica 0..100 s, a second 30..70 s; stages on replica 0.
        let mut fleet = FleetTimeline::new();
        fleet.provision(0, 0.0);
        fleet.online(0, 0.0);
        fleet.provision(1, 30.0);
        fleet.online(1, 40.0);
        fleet.drain_start(1, 60.0);
        fleet.offline(1, 70.0);
        fleet.close(100.0);
        let log = log_with(&[(5.0, 10.0, 300.0), (55.0, 20.0, 350.0)]);
        let prof =
            bin_stages_fleet(&cfg, &log, &fleet, 10.0, BinningBackend::Native).unwrap();
        let stage_j = 10.0 * 300.0 + 20.0 * 350.0;
        let live_s = 100.0 + 40.0;
        let covered_s = 30.0;
        let expect_j = stage_j + (live_s - covered_s) * 100.0;
        let total_j: f64 = prof.power_w.iter().sum::<f64>() * 10.0;
        assert!(
            (total_j - expect_j).abs() / expect_j < 1e-9,
            "binned {total_j} vs direct {expect_j}"
        );
    }

    #[test]
    fn multi_gpu_idle_fill() {
        let mut cfg = SimConfig::default();
        cfg.tp = 2;
        cfg.pp = 2; // 4 GPUs
        let log = StageLog::new();
        let prof = bin_stages(&cfg, &log, 60.0, 60.0, BinningBackend::Native).unwrap();
        assert!((prof.power_w[0] - 400.0).abs() < 1e-9); // 4 × idle
    }
}
