//! The Vidur→Vessim data pipeline (paper §3.2): timestamped batch-stage
//! power samples → Eq. 5 duration-weighted fixed-resolution bins →
//! Vessim-format load profile CSV.

pub mod binning;
pub mod profile;

pub use binning::{bin_stages, bin_stages_fleet, BinAccumulator, BinnedProfile, BinningBackend};
pub use profile::LoadProfile;
