//! Load-profile container: the CSV interchange between the inference
//! simulator and the co-simulation environment (the paper's §3.2
//! "Export" step — Vessim load-profile format).

use crate::pipeline::binning::BinnedProfile;
use crate::util::csv::Table;
use anyhow::Result;
use std::path::Path;

/// A fixed-resolution cluster power profile.
#[derive(Debug, Clone)]
pub struct LoadProfile {
    pub interval_s: f64,
    pub power_w: Vec<f64>,
}

impl LoadProfile {
    pub fn from_binned(b: &BinnedProfile) -> Self {
        LoadProfile {
            interval_s: b.interval_s,
            power_w: b.power_w.clone(),
        }
    }

    pub fn len(&self) -> usize {
        self.power_w.len()
    }
    pub fn is_empty(&self) -> bool {
        self.power_w.is_empty()
    }

    pub fn total_energy_kwh(&self) -> f64 {
        self.power_w.iter().sum::<f64>() * self.interval_s / 3.6e6
    }

    pub fn mean_power_w(&self) -> f64 {
        if self.power_w.is_empty() {
            0.0
        } else {
            self.power_w.iter().sum::<f64>() / self.power_w.len() as f64
        }
    }

    /// Repeat the profile until it spans at least `n` bins (the case
    /// study extends a shorter workload across a multi-day grid window).
    pub fn tile_to(&self, n: usize) -> LoadProfile {
        assert!(!self.power_w.is_empty());
        let mut power_w = Vec::with_capacity(n);
        while power_w.len() < n {
            let take = (n - power_w.len()).min(self.power_w.len());
            power_w.extend_from_slice(&self.power_w[..take]);
        }
        LoadProfile {
            interval_s: self.interval_s,
            power_w,
        }
    }

    /// Save in Vessim load-profile format (`t_s,value`).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut t = Table::new(&["t_s", "value"]);
        for (i, p) in self.power_w.iter().enumerate() {
            t.push_row(vec![
                format!("{:.1}", i as f64 * self.interval_s),
                format!("{p:.4}"),
            ]);
        }
        t.save(path)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<LoadProfile> {
        let t = Table::load(path)?;
        let ts = t.f64_col("t_s")?;
        let vs = t.f64_col("value")?;
        let interval_s = if ts.len() >= 2 { ts[1] - ts[0] } else { 60.0 };
        Ok(LoadProfile {
            interval_s,
            power_w: vs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let p = LoadProfile {
            interval_s: 60.0,
            power_w: vec![100.0, 250.5, 400.0],
        };
        let dir = std::env::temp_dir().join("vidur_energy_profile");
        let path = dir.join("load.csv");
        p.save(&path).unwrap();
        let back = LoadProfile::load(&path).unwrap();
        assert_eq!(back.interval_s, 60.0);
        assert_eq!(back.power_w.len(), 3);
        assert!((back.power_w[1] - 250.5).abs() < 1e-9);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn energy_and_mean() {
        let p = LoadProfile {
            interval_s: 3600.0,
            power_w: vec![1000.0, 2000.0],
        };
        assert!((p.total_energy_kwh() - 3.0).abs() < 1e-12);
        assert_eq!(p.mean_power_w(), 1500.0);
    }

    #[test]
    fn tiling_repeats() {
        let p = LoadProfile {
            interval_s: 60.0,
            power_w: vec![1.0, 2.0, 3.0],
        };
        let t = p.tile_to(7);
        assert_eq!(t.power_w, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0, 1.0]);
    }
}
