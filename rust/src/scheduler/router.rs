//! Cluster-level request router: round-robin (the paper's default
//! "RR") and least-outstanding-requests (vLLM production router
//! style).

use crate::config::simconfig::RouterKind;

/// Chooses the replica for each arriving request.
pub struct Router {
    kind: RouterKind,
    next: usize,
    n: usize,
}

impl Router {
    pub fn new(kind: RouterKind, replicas: usize) -> Self {
        assert!(replicas > 0);
        Router {
            kind,
            next: 0,
            n: replicas,
        }
    }

    /// Pick a replica given per-replica outstanding request counts.
    pub fn route(&mut self, outstanding: &[u64]) -> usize {
        debug_assert_eq!(outstanding.len(), self.n);
        match self.kind {
            RouterKind::RoundRobin => {
                let r = self.next;
                self.next = (self.next + 1) % self.n;
                r
            }
            RouterKind::LeastOutstanding => outstanding
                .iter()
                .enumerate()
                .min_by_key(|&(_, &o)| o)
                .map(|(i, _)| i)
                .unwrap_or(0),
        }
    }

    /// Pick a replica from an explicit eligible subset (dynamic fleets:
    /// draining/offline/cold-starting replicas are excluded by the
    /// caller). `outstanding` is indexed by absolute replica id.
    pub fn route_among(&mut self, eligible: &[usize], outstanding: &[u64]) -> usize {
        assert!(!eligible.is_empty(), "no routable replica");
        match self.kind {
            RouterKind::RoundRobin => {
                let r = eligible[self.next % eligible.len()];
                self.next = (self.next + 1) % eligible.len();
                r
            }
            RouterKind::LeastOutstanding => *eligible
                .iter()
                .min_by_key(|&&i| outstanding[i])
                .unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RouterKind::RoundRobin, 3);
        let o = vec![0, 0, 0];
        assert_eq!(
            (0..6).map(|_| r.route(&o)).collect::<Vec<_>>(),
            vec![0, 1, 2, 0, 1, 2]
        );
    }

    #[test]
    fn least_outstanding_picks_min() {
        let mut r = Router::new(RouterKind::LeastOutstanding, 3);
        assert_eq!(r.route(&[5, 2, 7]), 1);
        assert_eq!(r.route(&[0, 2, 7]), 0);
        // Tie: first wins (stable).
        assert_eq!(r.route(&[3, 3, 3]), 0);
    }

    #[test]
    fn route_among_respects_subset() {
        let mut r = Router::new(RouterKind::LeastOutstanding, 4);
        // Replica 0 has the global minimum but is not eligible.
        assert_eq!(r.route_among(&[1, 3], &[0, 5, 1, 2]), 3);

        let mut rr = Router::new(RouterKind::RoundRobin, 4);
        let picks: Vec<usize> = (0..4).map(|_| rr.route_among(&[1, 2], &[0; 4])).collect();
        assert_eq!(picks, vec![1, 2, 1, 2]);
    }
}
