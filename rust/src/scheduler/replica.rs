//! Replica-level scheduler: continuous batching with paged-KV admission
//! control, in the three policies the paper's ecosystem uses:
//!
//! * **vLLM** (paper default): prefill-prioritized — new prompts are
//!   prefilled in dedicated iterations (whole prompt at once, subject
//!   to a batched-token budget); decode iterations advance every
//!   running request by one token.
//! * **Sarathi**: chunked prefill — each iteration mixes all decodes
//!   with prefill chunks up to a token budget (`chunk_size`).
//! * **Orca**: iteration-level mixed batching without a token budget
//!   (simplified: admission still uses the paged KV cache).
//!
//! Preemption: if decode cannot grow its KV allocation, the
//! youngest running request is evicted and re-queued for
//! recompute-style restart (vLLM's recompute preemption, simplified to
//! re-prefill the original prompt).

use crate::cluster::kvcache::KvCache;
use crate::config::simconfig::{SchedulerKind, SimConfig};
use crate::workload::request::Phase;
use crate::workload::store::RequestStore;
use std::collections::VecDeque;

/// vLLM's max_num_batched_tokens default — caps prompt tokens per
/// prefill iteration.
pub const MAX_BATCHED_TOKENS: u64 = 8192;

/// What a stage is made of (for telemetry / figures).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    Prefill,
    Decode,
    Mixed,
}

/// One planned batch stage: request ids + the tokens each processes.
#[derive(Debug, Clone)]
pub struct StagePlan {
    pub entries: Vec<(u64, u32)>,
    pub kind: StageKind,
}

impl StagePlan {
    pub fn batch_size(&self) -> usize {
        self.entries.len()
    }
    pub fn total_new_tokens(&self) -> u64 {
        self.entries.iter().map(|&(_, t)| t as u64).sum()
    }
}

/// Per-replica scheduler state.
pub struct ReplicaScheduler {
    pub id: u32,
    kind: SchedulerKind,
    batch_cap: usize,
    chunk_size: u64,
    queue: VecDeque<u64>,
    running: Vec<u64>,
    kv: KvCache,
    pub preemptions: u64,
    /// Requests routed to this replica (for router load balancing).
    pub outstanding: u64,
    /// Graceful-drain mode (autoscaling scale-down): admission is
    /// closed, running requests finish; queued requests are re-routed
    /// by the caller via [`Self::drain_queue`].
    draining: bool,
}

impl ReplicaScheduler {
    pub fn new(id: u32, cfg: &SimConfig) -> crate::Result<Self> {
        let kv = KvCache::for_replica(
            cfg.model_spec()?,
            cfg.gpu_spec()?,
            cfg.tp,
            cfg.pp,
            cfg.kv_block_tokens,
            cfg.max_tokens,
        );
        Ok(ReplicaScheduler {
            id,
            kind: cfg.scheduler,
            batch_cap: cfg.batch_cap,
            chunk_size: cfg.chunk_size,
            queue: VecDeque::new(),
            running: Vec::new(),
            kv,
            preemptions: 0,
            outstanding: 0,
            draining: false,
        })
    }

    /// Test constructor with an explicit KV cache.
    pub fn with_kv(
        id: u32,
        kind: SchedulerKind,
        batch_cap: usize,
        chunk_size: u64,
        kv: KvCache,
    ) -> Self {
        ReplicaScheduler {
            id,
            kind,
            batch_cap,
            chunk_size,
            queue: VecDeque::new(),
            running: Vec::new(),
            kv,
            preemptions: 0,
            outstanding: 0,
            draining: false,
        }
    }

    pub fn enqueue(&mut self, id: u64) {
        self.queue.push_back(id);
        self.outstanding += 1;
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.running.is_empty()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }
    pub fn running_len(&self) -> usize {
        self.running.len()
    }
    pub fn kv(&self) -> &KvCache {
        &self.kv
    }

    /// Currently running request ids in admission order (oldest first).
    pub fn running_ids(&self) -> Vec<u64> {
        self.running.clone()
    }

    /// Enter graceful drain: stop admitting, let running requests
    /// finish. Idempotent.
    pub fn begin_drain(&mut self) {
        self.draining = true;
    }

    pub fn is_draining(&self) -> bool {
        self.draining
    }

    /// Remove and return every queued (not yet admitted) request so the
    /// caller can re-route it to another replica. Adjusts the
    /// outstanding counter accordingly.
    pub fn drain_queue(&mut self) -> Vec<u64> {
        let ids: Vec<u64> = self.queue.drain(..).collect();
        self.outstanding = self.outstanding.saturating_sub(ids.len() as u64);
        ids
    }

    /// Remove up to `n` queued requests from the back of the queue
    /// (newest first, preserving FIFO order for the rest) so the
    /// caller can rebalance them onto another replica — used when a
    /// newly-online replica takes its share of a standing backlog.
    pub fn steal_queued(&mut self, n: usize) -> Vec<u64> {
        let take = n.min(self.queue.len());
        let mut ids = Vec::with_capacity(take);
        for _ in 0..take {
            if let Some(id) = self.queue.pop_back() {
                ids.push(id);
            }
        }
        self.outstanding = self.outstanding.saturating_sub(ids.len() as u64);
        ids
    }

    /// Admit queued requests while capacity (batch cap + KV) allows.
    /// KV is reserved for the full prompt plus one decode block of
    /// headroom. Draining replicas admit nothing.
    fn admit<S: RequestStore + ?Sized>(&mut self, reqs: &mut S, now: f64) {
        if self.draining {
            return;
        }
        while self.running.len() < self.batch_cap {
            let Some(&id) = self.queue.front() else { break };
            let r = reqs.req_mut(id);
            let need = r.prefill_tokens + 1;
            if !self.kv.admit(id, need) {
                break; // head-of-line blocking, vLLM-style
            }
            r.scheduled_s.get_or_insert(now);
            self.queue.pop_front();
            self.running.push(id);
        }
    }

    /// Plan the next batch stage, or None if nothing can run.
    ///
    /// Allocating convenience wrapper around
    /// [`Self::next_stage_into`] (the engine hot path uses the latter
    /// with a pooled vector; see `sim::arena`).
    pub fn next_stage<S: RequestStore + ?Sized>(
        &mut self,
        reqs: &mut S,
        now: f64,
    ) -> Option<StagePlan> {
        let mut entries = Vec::new();
        let kind = self.next_stage_into(&mut *reqs, now, &mut entries)?;
        Some(StagePlan { entries, kind })
    }

    /// Plan the next batch stage into a caller-provided (cleared)
    /// entries buffer; returns the stage kind, or None if nothing can
    /// run (the buffer is left empty in that case).
    pub fn next_stage_into<S: RequestStore + ?Sized>(
        &mut self,
        reqs: &mut S,
        now: f64,
        entries: &mut Vec<(u64, u32)>,
    ) -> Option<StageKind> {
        entries.clear();
        self.admit(&mut *reqs, now);
        if self.running.is_empty() {
            return None;
        }
        match self.kind {
            SchedulerKind::Vllm => self.plan_vllm(&mut *reqs, entries),
            SchedulerKind::Sarathi => self.plan_sarathi(&mut *reqs, entries),
            SchedulerKind::Orca => self.plan_orca(&mut *reqs, entries),
        }
    }

    fn plan_vllm<S: RequestStore + ?Sized>(
        &mut self,
        reqs: &mut S,
        entries: &mut Vec<(u64, u32)>,
    ) -> Option<StageKind> {
        // Prefill-prioritized: if any running request still has prompt
        // tokens, run a prefill-only stage (whole prompts, token budget).
        let mut budget = MAX_BATCHED_TOKENS;
        for &id in &self.running {
            let r = reqs.req(id);
            let rem = r.prefill_remaining();
            if rem > 0 && budget >= rem.min(budget) && budget > 0 {
                let take = rem.min(budget);
                entries.push((id, take as u32));
                budget -= take;
            }
        }
        if !entries.is_empty() {
            return Some(StageKind::Prefill);
        }
        self.plan_decode(&mut *reqs, entries)
    }

    fn plan_decode<S: RequestStore + ?Sized>(
        &mut self,
        reqs: &mut S,
        entries: &mut Vec<(u64, u32)>,
    ) -> Option<StageKind> {
        // Grow KV by one token per running decode request; preempt the
        // youngest on allocation failure.
        loop {
            let mut ok = true;
            for idx in 0..self.running.len() {
                let id = self.running[idx];
                let r = reqs.req(id);
                if r.phase() == Phase::Decode
                    && !self.kv.grow(id, r.context_len() + 1)
                {
                    ok = false;
                    break;
                }
            }
            if ok {
                break;
            }
            self.preempt_youngest(&mut *reqs);
            if self.running.is_empty() {
                return None;
            }
        }
        entries.extend(
            self.running
                .iter()
                .filter(|&&id| reqs.req(id).phase() == Phase::Decode)
                .map(|&id| (id, 1u32)),
        );
        if entries.is_empty() {
            None
        } else {
            Some(StageKind::Decode)
        }
    }

    fn plan_sarathi<S: RequestStore + ?Sized>(
        &mut self,
        reqs: &mut S,
        entries: &mut Vec<(u64, u32)>,
    ) -> Option<StageKind> {
        // Mixed stage: all decodes first (1 token each), then prefill
        // chunks into the remaining token budget.
        self.plan_decode(&mut *reqs, entries);
        let mut budget = self.chunk_size.saturating_sub(entries.len() as u64);
        let had_decodes = !entries.is_empty();
        for &id in &self.running {
            if budget == 0 {
                break;
            }
            let r = reqs.req(id);
            let rem = r.prefill_remaining();
            if rem > 0 {
                let take = rem.min(budget);
                entries.push((id, take as u32));
                budget -= take;
            }
        }
        if entries.is_empty() {
            return None;
        }
        let kind = if had_decodes && entries.len() > self.count_decodes(&*reqs) {
            StageKind::Mixed
        } else if had_decodes {
            StageKind::Decode
        } else {
            StageKind::Prefill
        };
        Some(kind)
    }

    fn count_decodes<S: RequestStore + ?Sized>(&self, reqs: &S) -> usize {
        self.running
            .iter()
            .filter(|&&id| reqs.req(id).phase() == Phase::Decode)
            .count()
    }

    fn plan_orca<S: RequestStore + ?Sized>(
        &mut self,
        reqs: &mut S,
        entries: &mut Vec<(u64, u32)>,
    ) -> Option<StageKind> {
        // Iteration-level mixed batch: full remaining prompts + all
        // decodes, no token budget.
        self.plan_decode(&mut *reqs, entries);
        let had_decodes = !entries.is_empty();
        let mut had_prefill = false;
        for &id in &self.running {
            let r = reqs.req(id);
            let rem = r.prefill_remaining();
            if rem > 0 {
                entries.push((id, rem as u32));
                had_prefill = true;
            }
        }
        if entries.is_empty() {
            return None;
        }
        let kind = match (had_prefill, had_decodes) {
            (true, true) => StageKind::Mixed,
            (true, false) => StageKind::Prefill,
            _ => StageKind::Decode,
        };
        Some(kind)
    }

    fn preempt_youngest<S: RequestStore + ?Sized>(&mut self, reqs: &mut S) {
        // Youngest = most recently admitted (vLLM preempts the lowest
        // priority request and restarts it by recomputation).
        if let Some(id) = self.running.pop() {
            self.kv.release(id);
            let r = reqs.req_mut(id);
            r.prefill_done = 0; // recompute-style restart
            self.queue.push_front(id);
            self.preemptions += 1;
        }
    }

    /// Apply a completed stage: advance progress, emit first tokens,
    /// retire finished requests. Returns the finished request ids.
    ///
    /// Allocating wrapper around [`Self::complete_stage_into`].
    pub fn complete_stage<S: RequestStore + ?Sized>(
        &mut self,
        reqs: &mut S,
        plan: &StagePlan,
        now: f64,
    ) -> Vec<u64> {
        let mut finished = Vec::new();
        self.complete_stage_into(&mut *reqs, &plan.entries, now, &mut finished);
        finished
    }

    /// Apply a completed stage, appending finished request ids to a
    /// caller-provided buffer (clear it first; the engine reuses one
    /// per run).
    pub fn complete_stage_into<S: RequestStore + ?Sized>(
        &mut self,
        reqs: &mut S,
        entries: &[(u64, u32)],
        now: f64,
        finished: &mut Vec<u64>,
    ) {
        let first_new = finished.len();
        for &(id, nt) in entries {
            let r = reqs.req_mut(id);
            if r.prefill_remaining() > 0 {
                r.prefill_done += nt as u64;
                debug_assert!(r.prefill_done <= r.prefill_tokens);
                if r.prefill_done == r.prefill_tokens {
                    // The completing prefill iteration emits the first
                    // output token (vLLM semantics).
                    r.decode_done += 1;
                    r.first_token_s.get_or_insert(now);
                }
            } else {
                r.decode_done += 1;
                r.first_token_s.get_or_insert(now);
            }
            if r.decode_done >= r.decode_tokens {
                r.finished_s = Some(now);
                finished.push(id);
            }
        }
        for id in &finished[first_new..] {
            self.kv.release(*id);
            self.running.retain(|x| x != id);
            self.outstanding = self.outstanding.saturating_sub(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::kvcache::KvCache;
    use crate::workload::request::Request;

    fn mk_reqs(specs: &[(u64, u64)]) -> Vec<Request> {
        specs
            .iter()
            .enumerate()
            .map(|(i, &(p, d))| Request::new(i as u64, 0.0, p, d))
            .collect()
    }

    fn vllm_sched(cap: usize, blocks: u64) -> ReplicaScheduler {
        ReplicaScheduler::with_kv(
            0,
            SchedulerKind::Vllm,
            cap,
            512,
            KvCache::with_blocks(16, blocks),
        )
    }

    #[test]
    fn vllm_prefill_then_decode() {
        let mut reqs = mk_reqs(&[(100, 3), (50, 2)]);
        let mut s = vllm_sched(128, 1000);
        s.enqueue(0);
        s.enqueue(1);

        // Stage 1: both prompts prefilled together.
        let p1 = s.next_stage(&mut reqs, 0.0).unwrap();
        assert_eq!(p1.kind, StageKind::Prefill);
        assert_eq!(p1.total_new_tokens(), 150);
        let fin = s.complete_stage(&mut reqs, &p1, 0.5);
        assert!(fin.is_empty());
        // Prefill completion emitted first tokens.
        assert_eq!(reqs[0].decode_done, 1);
        assert_eq!(reqs[0].first_token_s, Some(0.5));

        // Stage 2: decode for both.
        let p2 = s.next_stage(&mut reqs, 0.5).unwrap();
        assert_eq!(p2.kind, StageKind::Decode);
        assert_eq!(p2.batch_size(), 2);
        let fin = s.complete_stage(&mut reqs, &p2, 0.6);
        // Request 1 wanted 2 tokens: 1 from prefill + 1 now -> done.
        assert_eq!(fin, vec![1]);
        assert!(reqs[1].is_finished());

        // Stage 3: only request 0 decodes.
        let p3 = s.next_stage(&mut reqs, 0.6).unwrap();
        assert_eq!(p3.batch_size(), 1);
        let fin = s.complete_stage(&mut reqs, &p3, 0.7);
        assert_eq!(fin, vec![0]);
        assert!(!s.has_work());
    }

    #[test]
    fn batch_cap_respected() {
        let n = 10;
        let mut reqs = mk_reqs(&vec![(10, 5); n]);
        let mut s = vllm_sched(4, 10_000);
        for i in 0..n as u64 {
            s.enqueue(i);
        }
        let p = s.next_stage(&mut reqs, 0.0).unwrap();
        assert_eq!(p.batch_size(), 4);
        assert_eq!(s.queue_len(), 6);
    }

    #[test]
    fn kv_admission_blocks_when_full() {
        // 10 blocks of 16 = 160 tokens capacity; each request needs
        // 100+1 tokens -> 7 blocks. Only one fits.
        let mut reqs = mk_reqs(&[(100, 2), (100, 2)]);
        let mut s = vllm_sched(128, 10);
        s.enqueue(0);
        s.enqueue(1);
        let p = s.next_stage(&mut reqs, 0.0).unwrap();
        assert_eq!(p.batch_size(), 1);
        assert_eq!(s.queue_len(), 1);
    }

    #[test]
    fn preemption_on_kv_exhaustion() {
        // Tight cache: two requests admitted, but decode growth
        // eventually exhausts blocks and preempts the youngest.
        let mut reqs = mk_reqs(&[(17, 200), (17, 200)]);
        let mut s = vllm_sched(128, 4); // 64 tokens total
        s.enqueue(0);
        s.enqueue(1);
        let mut now = 0.0;
        let mut preempted = false;
        for _ in 0..200 {
            let Some(p) = s.next_stage(&mut reqs, now) else { break };
            now += 0.01;
            s.complete_stage(&mut reqs, &p, now);
            if s.preemptions > 0 {
                preempted = true;
                break;
            }
        }
        assert!(preempted, "expected a preemption with a tiny KV cache");
        s.kv().check_invariants().unwrap();
    }

    #[test]
    fn sarathi_mixes_decode_and_chunked_prefill() {
        let mut reqs = mk_reqs(&[(2000, 5), (1000, 5)]);
        let mut s = ReplicaScheduler::with_kv(
            0,
            SchedulerKind::Sarathi,
            128,
            512,
            KvCache::with_blocks(16, 10_000),
        );
        s.enqueue(0);
        // First stage: chunked prefill of request 0 only (budget 512).
        let p1 = s.next_stage(&mut reqs, 0.0).unwrap();
        assert_eq!(p1.kind, StageKind::Prefill);
        assert_eq!(p1.total_new_tokens(), 512);
        s.complete_stage(&mut reqs, &p1, 0.1);
        assert_eq!(reqs[0].prefill_done, 512);
        // Enqueue request 1; stages keep chunking.
        s.enqueue(1);
        let p2 = s.next_stage(&mut reqs, 0.1).unwrap();
        assert_eq!(p2.total_new_tokens(), 512);
        // Run request 0 to decode phase, then stages must be Mixed.
        let mut now = 0.2;
        loop {
            let Some(p) = s.next_stage(&mut reqs, now) else { break };
            now += 0.01;
            s.complete_stage(&mut reqs, &p, now);
            if p.kind == StageKind::Mixed {
                // Decodes piggybacked with prefill chunks.
                assert!(p.entries.iter().any(|&(_, t)| t == 1));
                assert!(p.entries.iter().any(|&(_, t)| t > 1));
                return;
            }
            if now > 10.0 {
                break;
            }
        }
        panic!("sarathi never produced a mixed stage");
    }

    #[test]
    fn orca_runs_full_prompts_with_decodes() {
        let mut reqs = mk_reqs(&[(300, 10), (400, 10)]);
        let mut s = ReplicaScheduler::with_kv(
            0,
            SchedulerKind::Orca,
            128,
            512,
            KvCache::with_blocks(16, 10_000),
        );
        s.enqueue(0);
        let p1 = s.next_stage(&mut reqs, 0.0).unwrap();
        s.complete_stage(&mut reqs, &p1, 0.1);
        s.enqueue(1);
        // Next stage mixes request 0's decode with request 1's FULL prompt.
        let p2 = s.next_stage(&mut reqs, 0.1).unwrap();
        assert_eq!(p2.kind, StageKind::Mixed);
        let prefill_tokens: u64 = p2
            .entries
            .iter()
            .filter(|&&(_, t)| t > 1)
            .map(|&(_, t)| t as u64)
            .sum();
        assert_eq!(prefill_tokens, 400); // unchunked
    }

    #[test]
    fn draining_replica_admits_nothing_but_finishes_running() {
        let mut reqs = mk_reqs(&[(50, 3), (50, 3), (50, 3)]);
        let mut s = vllm_sched(128, 1000);
        s.enqueue(0);
        let p = s.next_stage(&mut reqs, 0.0).unwrap();
        s.complete_stage(&mut reqs, &p, 0.1);
        assert_eq!(s.running_len(), 1);

        assert!(!s.is_draining());
        s.begin_drain();
        assert!(s.is_draining());
        s.enqueue(1);
        s.enqueue(2);
        // Queued requests never get admitted while draining.
        let mut now = 0.1;
        loop {
            let Some(p) = s.next_stage(&mut reqs, now) else { break };
            assert!(
                p.entries.iter().all(|&(id, _)| id == 0),
                "drained replica admitted new work: {p:?}"
            );
            now += 0.01;
            s.complete_stage(&mut reqs, &p, now);
        }
        assert!(reqs[0].is_finished());
        assert_eq!(s.running_len(), 0);
        assert_eq!(s.queue_len(), 2);
        // The leftover queue re-routes elsewhere.
        let moved = s.drain_queue();
        assert_eq!(moved, vec![1, 2]);
        assert_eq!(s.outstanding, 0);
        assert!(!s.has_work());
    }

    #[test]
    fn work_conservation_all_requests_finish() {
        let mut reqs = mk_reqs(&vec![(64, 16); 50]);
        let mut s = vllm_sched(8, 2000);
        for i in 0..50 {
            s.enqueue(i);
        }
        let mut now = 0.0;
        let mut finished = 0;
        for _ in 0..100_000 {
            let Some(p) = s.next_stage(&mut reqs, now) else { break };
            now += 0.01;
            finished += s.complete_stage(&mut reqs, &p, now).len();
            if finished == 50 {
                break;
            }
        }
        assert_eq!(finished, 50, "not all requests completed");
        assert!(!s.has_work());
        s.kv().check_invariants().unwrap();
        assert_eq!(s.kv().used_blocks(), 0);
    }
}
