//! Scheduling layer: replica-level continuous-batching policies (vLLM,
//! Sarathi, Orca) and the cluster-level request router.

pub mod replica;
pub mod router;

pub use replica::{ReplicaScheduler, StageKind, StagePlan};
pub use router::Router;
