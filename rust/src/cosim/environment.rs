//! The co-simulation environment: steps the microgrid over the load /
//! solar / carbon-intensity signals at a fixed resolution and produces
//! the Table-2 summary metrics.
//!
//! Two execution backends:
//! * `run_native` — pure-rust step loop; required when an active
//!   controller rewrites the load (feedback in the loop);
//! * `run_hlo` — the AOT cosim kernel (`artifacts/cosim_step.hlo.txt`)
//!   executed in 1440-step (one-day) chunks via PJRT, chaining the
//!   battery SoC across chunks. Monitor-only (no feedback), and
//!   bit-matched against the native loop in rust/tests/cosim_parity.rs.

use crate::battery::Battery;
use crate::config::simconfig::CosimConfig;
use crate::cosim::controllers::{CarbonAwareController, ControllerAction};
use crate::cosim::microgrid::{Microgrid, StepRecord};
use crate::grid::{CarbonIntensityTrace, HistoricalSignal, SolarModel};
use crate::runtime::{artifacts, pjrt::cached_executable};
use crate::util::json::Value;
use anyhow::Result;

/// The synthetic Solcast/WattTime substitutes (DESIGN.md §5) as
/// resampleable signals spanning `n` co-simulation steps, seeded and
/// offset from the cosim config (shared by the case study, the
/// autoscaling experiment, and the examples).
pub fn default_signal_traces(
    cosim: &CosimConfig,
    n: usize,
) -> (HistoricalSignal, HistoricalSignal) {
    let start_s = cosim.start_hour * 3600.0;
    let solar = SolarModel {
        capacity_w: cosim.solar_capacity_w,
        seed: cosim.seed,
        ..SolarModel::default()
    };
    let ci_model = CarbonIntensityTrace {
        mean: cosim.ci_mean,
        seed: cosim.seed ^ 0xC1,
        ..CarbonIntensityTrace::default()
    };
    (solar.trace(start_s, n), ci_model.trace(start_s, n))
}

/// [`default_signal_traces`] sampled onto the co-simulation step grid:
/// `(solar_w, ci)` vectors of length `n`. The load side of the
/// environment — fixed-fleet or time-varying under autoscaling — comes
/// from the Eq. 5 binned profile ([`crate::pipeline`]).
pub fn default_signals(cosim: &CosimConfig, n: usize) -> (Vec<f64>, Vec<f64>) {
    let start_s = cosim.start_hour * 3600.0;
    let (solar_sig, ci_sig) = default_signal_traces(cosim, n);
    (
        solar_sig.sample_grid(start_s, n, cosim.interval_s),
        ci_sig.sample_grid(start_s, n, cosim.interval_s),
    )
}

/// Summary of a co-simulation run (the paper's Table 2).
#[derive(Debug, Clone)]
pub struct CosimResult {
    pub records: Vec<StepRecord>,
    // --- energy ---
    pub total_energy_kwh: f64,
    pub solar_generation_kwh: f64,
    pub grid_consumption_kwh: f64,
    pub grid_export_kwh: f64,
    pub renewable_share: f64,
    pub grid_dependency: f64,
    // --- emissions ---
    /// Gross emissions if all load had been grid-supplied, kg.
    pub total_emissions_kg: f64,
    /// Emissions avoided by solar + storage, kg.
    pub offset_by_solar_kg: f64,
    /// Actual import emissions, g.
    pub net_footprint_g: f64,
    pub carbon_offset_frac: f64,
    pub avg_ci: f64,
    pub hours_high_ci: f64,
    // --- battery ---
    pub avg_soc: f64,
    pub hours_below_50_soc: f64,
    pub hours_above_80_soc: f64,
    pub charging_frac: f64,
    pub discharging_frac: f64,
    pub idle_frac: f64,
    pub battery_full_cycles: f64,
}

impl CosimResult {
    fn from_records(records: Vec<StepRecord>, grid: &Microgrid, ci_high: f64, dt_s: f64) -> Self {
        let dt_h = dt_s / 3600.0;
        let n = records.len().max(1) as f64;
        let gross_g: f64 = records
            .iter()
            .map(|r| r.load_w * dt_h / 1000.0 * r.ci)
            .sum();
        let net_g: f64 = records.iter().map(|r| r.emissions_g).sum();
        let avg_ci = records.iter().map(|r| r.ci).sum::<f64>() / n;
        let hours_high_ci = records.iter().filter(|r| r.ci > ci_high).count() as f64 * dt_h;
        let avg_soc = records.iter().map(|r| r.soc).sum::<f64>() / n;
        let below50 = records.iter().filter(|r| r.soc < 0.5).count() as f64 * dt_h;
        let above80 = records.iter().filter(|r| r.soc >= 0.7999).count() as f64 * dt_h;
        let charging = records.iter().filter(|r| r.battery_w < -1e-9).count() as f64 / n;
        let discharging = records.iter().filter(|r| r.battery_w > 1e-9).count() as f64 / n;

        CosimResult {
            total_energy_kwh: grid.total_load_wh / 1000.0,
            solar_generation_kwh: grid.total_solar_wh / 1000.0,
            grid_consumption_kwh: grid.total_import_wh / 1000.0,
            grid_export_kwh: grid.total_export_wh / 1000.0,
            renewable_share: grid.renewable_share(),
            grid_dependency: grid.grid_dependency(),
            total_emissions_kg: gross_g / 1000.0,
            offset_by_solar_kg: (gross_g - net_g) / 1000.0,
            net_footprint_g: net_g,
            carbon_offset_frac: if gross_g > 0.0 {
                (gross_g - net_g) / gross_g
            } else {
                0.0
            },
            avg_ci,
            hours_high_ci,
            avg_soc,
            hours_below_50_soc: below50,
            hours_above_80_soc: above80,
            charging_frac: charging,
            discharging_frac: discharging,
            idle_frac: 1.0 - charging - discharging,
            battery_full_cycles: grid.battery.full_cycles(),
            records,
        }
    }

    /// Table-2-shaped JSON.
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("total_energy_kwh", self.total_energy_kwh)
            .set("solar_generation_kwh", self.solar_generation_kwh)
            .set("grid_consumption_kwh", self.grid_consumption_kwh)
            .set("grid_export_kwh", self.grid_export_kwh)
            .set("renewable_share", self.renewable_share)
            .set("grid_dependency", self.grid_dependency)
            .set("total_emissions_kg", self.total_emissions_kg)
            .set("offset_by_solar_kg", self.offset_by_solar_kg)
            .set("net_footprint_g", self.net_footprint_g)
            .set("carbon_offset_frac", self.carbon_offset_frac)
            .set("avg_ci", self.avg_ci)
            .set("hours_high_ci", self.hours_high_ci)
            .set("avg_soc", self.avg_soc)
            .set("hours_below_50_soc", self.hours_below_50_soc)
            .set("hours_above_80_soc", self.hours_above_80_soc)
            .set("charging_frac", self.charging_frac)
            .set("discharging_frac", self.discharging_frac)
            .set("idle_frac", self.idle_frac)
            .set("battery_full_cycles", self.battery_full_cycles);
        v
    }
}

/// The stepped environment.
pub struct Environment {
    pub config: CosimConfig,
    pub controller: Option<CarbonAwareController>,
}

impl Environment {
    pub fn new(config: CosimConfig) -> Self {
        Environment {
            config,
            controller: None,
        }
    }

    pub fn with_controller(mut self, c: CarbonAwareController) -> Self {
        self.controller = Some(c);
        self
    }

    /// Native step loop. `load`, `solar`, `ci` are per-step series of
    /// equal length (sampled at `config.interval_s`).
    pub fn run_native(
        &mut self,
        load_w: &[f64],
        solar_w: &[f64],
        ci: &[f64],
    ) -> Result<CosimResult> {
        anyhow::ensure!(
            load_w.len() == solar_w.len() && load_w.len() == ci.len(),
            "signal length mismatch"
        );
        let dt = self.config.interval_s;
        let mut grid = Microgrid::new(Battery::from_config(&self.config));
        let mut records = Vec::with_capacity(load_w.len());
        for i in 0..load_w.len() {
            let t = i as f64 * dt;
            let mut eff_load = load_w[i];
            if let Some(c) = self.controller.as_mut() {
                if let ControllerAction::Shift { run_w, .. } =
                    c.decide(load_w[i], ci[i], solar_w[i], dt)
                {
                    eff_load = run_w;
                }
            }
            records.push(grid.step(t, eff_load, solar_w[i], ci[i], dt));
        }
        // Work conservation: drain any residual backlog at the end.
        if let Some(c) = self.controller.as_mut() {
            let mut t = load_w.len() as f64 * dt;
            let mut guard = 0;
            while c.residual_wh() > 1e-6 && guard < 100_000 {
                let drain = c.drain_w.min(c.residual_wh() * 3600.0 / dt);
                let last_ci = *ci.last().unwrap_or(&0.0);
                if let ControllerAction::Shift { run_w, .. } =
                    c.decide(0.0, 0.0, 0.0, dt)
                {
                    records.push(grid.step(t, run_w, 0.0, last_ci, dt));
                } else {
                    records.push(grid.step(t, drain, 0.0, last_ci, dt));
                    c.drained_wh_total += drain * dt / 3600.0;
                }
                t += dt;
                guard += 1;
            }
        }
        Ok(CosimResult::from_records(
            records,
            &grid,
            self.config.ci_high,
            dt,
        ))
    }

    /// AOT cosim kernel in day-sized chunks via PJRT (monitor-only).
    pub fn run_hlo(
        &mut self,
        load_w: &[f64],
        solar_w: &[f64],
        ci: &[f64],
    ) -> Result<CosimResult> {
        anyhow::ensure!(
            self.controller.is_none(),
            "the HLO cosim backend has no controller feedback; use run_native"
        );
        let exe = cached_executable("cosim_step")?;
        let t_chunk = artifacts::T_COSIM;
        let dt = self.config.interval_s;

        // The rust battery tracks cumulative counters; the kernel owns
        // the step dynamics. We mirror the counters from outputs.
        let mut grid = Microgrid::new(Battery::from_config(&self.config));
        let mut soc = self.config.soc_init as f32;
        let bp: Vec<f32> = grid.battery.param_vec(dt).to_vec();
        let mut records = Vec::with_capacity(load_w.len());

        let mut i = 0usize;
        while i < load_w.len() {
            let n = (load_w.len() - i).min(t_chunk);
            let mut lw = vec![0f32; t_chunk];
            let mut sw = vec![0f32; t_chunk];
            let mut cw = vec![0f32; t_chunk];
            for k in 0..n {
                lw[k] = load_w[i + k] as f32;
                sw[k] = solar_w[i + k] as f32;
                cw[k] = ci[i + k] as f32;
            }
            let out = exe.call_f32(&[&lw, &sw, &cw, &bp, &[soc]])?;
            anyhow::ensure!(out.len() == 5, "cosim kernel returned {} outputs", out.len());
            let (soc_arr, grid_arr, used_arr, batt_arr, em_arr) =
                (&out[0], &out[1], &out[2], &out[3], &out[4]);
            let dt_h = dt / 3600.0;
            for k in 0..n {
                let t_s = (i + k) as f64 * dt;
                let rec = StepRecord {
                    t_s,
                    load_w: load_w[i + k],
                    solar_w: solar_w[i + k],
                    solar_used_w: used_arr[k] as f64,
                    grid_w: grid_arr[k] as f64,
                    battery_w: batt_arr[k] as f64,
                    soc: soc_arr[k] as f64,
                    ci: ci[i + k],
                    emissions_g: em_arr[k] as f64,
                };
                // Mirror cumulative counters.
                grid.total_load_wh += rec.load_w * dt_h;
                grid.total_solar_wh += rec.solar_w * dt_h;
                grid.total_solar_used_wh += rec.solar_used_w * dt_h;
                grid.total_import_wh += rec.grid_w.max(0.0) * dt_h;
                grid.total_export_wh += (-rec.grid_w).max(0.0) * dt_h;
                grid.total_emissions_g += rec.emissions_g;
                grid.battery.discharged_wh += rec.battery_w.max(0.0) * dt_h;
                grid.battery.charged_wh += (-rec.battery_w).max(0.0) * dt_h;
                records.push(rec);
            }
            soc = soc_arr[n - 1];
            i += n;
        }
        grid.battery.soc = soc as f64;
        Ok(CosimResult::from_records(
            records,
            &grid,
            self.config.ci_high,
            dt,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat(v: f64, n: usize) -> Vec<f64> {
        vec![v; n]
    }

    #[test]
    fn native_monitor_run_sums_energy() {
        let mut env = Environment::new(CosimConfig::default());
        let n = 120; // 2 h
        let res = env
            .run_native(&flat(500.0, n), &flat(200.0, n), &flat(300.0, n))
            .unwrap();
        assert!((res.total_energy_kwh - 1.0).abs() < 1e-9); // 500 W * 2 h
        assert!((res.solar_generation_kwh - 0.4).abs() < 1e-9);
        assert!(res.grid_consumption_kwh > 0.0);
        assert!(res.renewable_share > 0.3 && res.renewable_share < 0.6);
        assert_eq!(res.records.len(), n);
    }

    #[test]
    fn offset_accounting_consistent() {
        let mut env = Environment::new(CosimConfig::default());
        let n = 240;
        let res = env
            .run_native(&flat(400.0, n), &flat(300.0, n), &flat(418.2, n))
            .unwrap();
        // total = offset + net (Table 2 identity).
        let total = res.total_emissions_kg * 1000.0;
        let sum = res.offset_by_solar_kg * 1000.0 + res.net_footprint_g;
        assert!((total - sum).abs() < 1e-6);
        assert!(res.carbon_offset_frac > 0.5); // 300 of 400 W solar
    }

    #[test]
    fn controller_reduces_net_emissions() {
        // Two dirty hours then two clean hours, flat load, no solar:
        // shifting to the clean window must cut net emissions.
        let mut ci = flat(500.0, 120);
        ci.extend(flat(60.0, 120));
        let load = flat(400.0, 240);
        let solar = flat(0.0, 240);

        let mut base_env = Environment::new(CosimConfig::default());
        let base = base_env.run_native(&load, &solar, &ci).unwrap();

        let mut aware_env = Environment::new(CosimConfig::default())
            .with_controller(CarbonAwareController::new(100.0, 200.0, 0.6));
        let aware = aware_env.run_native(&load, &solar, &ci).unwrap();

        assert!(
            aware.net_footprint_g < 0.9 * base.net_footprint_g,
            "aware {} !<< base {}",
            aware.net_footprint_g,
            base.net_footprint_g
        );
        // Work conservation: same total energy (within drain rounding).
        assert!(
            (aware.total_energy_kwh - base.total_energy_kwh).abs()
                < 0.01 * base.total_energy_kwh
        );
    }

    #[test]
    fn high_ci_hours_counted() {
        let mut env = Environment::new(CosimConfig::default());
        let mut ci = flat(250.0, 60); // 1 h above 200
        ci.extend(flat(150.0, 60)); // 1 h below
        let res = env
            .run_native(&flat(100.0, 120), &flat(0.0, 120), &ci)
            .unwrap();
        assert!((res.hours_high_ci - 1.0).abs() < 1e-9);
    }
}
