//! Co-simulation controllers — Vessim's Monitor/CarbonLogger roles are
//! folded into the environment's step records; this module implements
//! the *active* controller the paper's discussion calls for:
//! carbon-aware load shifting against the CI thresholds of Table 1b
//! (100 / 200 gCO₂/kWh).
//!
//! Policy: when the grid is dirty (CI > high threshold) a configurable
//! fraction of the load is deferred into a bounded backlog; when the
//! grid is clean (CI < low threshold) — or a deferral deadline expires
//! — backlog drains back on top of the live load. This models the
//! "shift inference to renewable peaks" strategy (§5) without changing
//! total work done.

/// Per-step decision of a controller.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ControllerAction {
    /// Run the offered load unchanged.
    Proceed,
    /// Run `run_w` now and defer the rest.
    Shift { run_w: f64, defer_w: f64 },
}

/// Threshold-based carbon-aware load shifter.
#[derive(Debug, Clone)]
pub struct CarbonAwareController {
    /// Above this CI (g/kWh) load is deferred (paper: 200).
    pub ci_high: f64,
    /// Below this CI backlog drains aggressively (paper: 100).
    pub ci_low: f64,
    /// Fraction of load that is deferrable (batch/offline share).
    pub deferrable_fraction: f64,
    /// Max backlog, Wh (beyond this, load runs regardless).
    pub max_backlog_wh: f64,
    /// Drain power when the grid is clean, W.
    pub drain_w: f64,
    backlog_wh: f64,
    pub deferred_wh_total: f64,
    pub drained_wh_total: f64,
}

impl CarbonAwareController {
    pub fn new(ci_low: f64, ci_high: f64, deferrable_fraction: f64) -> Self {
        CarbonAwareController {
            ci_high,
            ci_low,
            deferrable_fraction: deferrable_fraction.clamp(0.0, 1.0),
            max_backlog_wh: 1000.0,
            drain_w: 300.0,
            backlog_wh: 0.0,
            deferred_wh_total: 0.0,
            drained_wh_total: 0.0,
        }
    }

    pub fn backlog_wh(&self) -> f64 {
        self.backlog_wh
    }

    /// Decide this step's effective load.
    pub fn decide(&mut self, load_w: f64, ci: f64, solar_w: f64, dt_s: f64) -> ControllerAction {
        let dt_h = dt_s / 3600.0;
        // Dirty grid and not solar-covered: defer what we can.
        if ci > self.ci_high && solar_w < load_w {
            let deferrable = (load_w - solar_w).min(load_w * self.deferrable_fraction);
            let room = (self.max_backlog_wh - self.backlog_wh).max(0.0);
            let defer_w = deferrable.min(room / dt_h.max(1e-12));
            if defer_w > 1e-9 {
                self.backlog_wh += defer_w * dt_h;
                self.deferred_wh_total += defer_w * dt_h;
                return ControllerAction::Shift {
                    run_w: load_w - defer_w,
                    defer_w,
                };
            }
            return ControllerAction::Proceed;
        }
        // Clean grid (or surplus solar): drain the backlog.
        if self.backlog_wh > 1e-9 && (ci < self.ci_low || solar_w > load_w) {
            let drain = self.drain_w.min(self.backlog_wh / dt_h.max(1e-12));
            self.backlog_wh -= drain * dt_h;
            self.drained_wh_total += drain * dt_h;
            return ControllerAction::Shift {
                run_w: load_w + drain,
                defer_w: -drain,
            };
        }
        ControllerAction::Proceed
    }

    /// Energy still deferred at the end of a run (must be ~0 for a
    /// work-conserving comparison; drained by the environment's
    /// cooldown extension).
    pub fn residual_wh(&self) -> f64 {
        self.backlog_wh
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defers_on_dirty_grid() {
        let mut c = CarbonAwareController::new(100.0, 200.0, 0.5);
        match c.decide(400.0, 300.0, 0.0, 60.0) {
            ControllerAction::Shift { run_w, defer_w } => {
                assert_eq!(defer_w, 200.0); // 50% deferrable
                assert_eq!(run_w, 200.0);
            }
            a => panic!("expected shift, got {a:?}"),
        }
        assert!((c.backlog_wh() - 200.0 / 60.0).abs() < 1e-9);
    }

    #[test]
    fn proceeds_on_moderate_grid() {
        let mut c = CarbonAwareController::new(100.0, 200.0, 0.5);
        assert_eq!(c.decide(400.0, 150.0, 0.0, 60.0), ControllerAction::Proceed);
        assert_eq!(c.backlog_wh(), 0.0);
    }

    #[test]
    fn drains_on_clean_grid() {
        let mut c = CarbonAwareController::new(100.0, 200.0, 0.5);
        c.decide(400.0, 300.0, 0.0, 60.0); // build backlog
        let b0 = c.backlog_wh();
        match c.decide(100.0, 80.0, 0.0, 60.0) {
            ControllerAction::Shift { run_w, .. } => {
                assert!(run_w > 100.0);
                assert!(c.backlog_wh() < b0);
            }
            a => panic!("expected drain, got {a:?}"),
        }
    }

    #[test]
    fn drains_on_solar_surplus_even_if_dirty() {
        let mut c = CarbonAwareController::new(100.0, 200.0, 0.5);
        c.decide(400.0, 300.0, 0.0, 60.0);
        // CI still high but solar exceeds load: drain.
        match c.decide(100.0, 300.0, 500.0, 60.0) {
            ControllerAction::Shift { run_w, .. } => assert!(run_w > 100.0),
            a => panic!("expected drain, got {a:?}"),
        }
    }

    #[test]
    fn backlog_bounded() {
        let mut c = CarbonAwareController::new(100.0, 200.0, 1.0);
        c.max_backlog_wh = 10.0;
        for _ in 0..100 {
            c.decide(600.0, 400.0, 0.0, 60.0);
        }
        assert!(c.backlog_wh() <= 10.0 + 1e-9);
    }

    #[test]
    fn energy_conserved_defer_equals_drain() {
        let mut c = CarbonAwareController::new(100.0, 200.0, 0.5);
        for _ in 0..30 {
            c.decide(400.0, 350.0, 0.0, 60.0);
        }
        for _ in 0..600 {
            c.decide(50.0, 60.0, 0.0, 60.0);
        }
        assert!(c.residual_wh() < 1e-6);
        assert!((c.deferred_wh_total - c.drained_wh_total).abs() < 1e-6);
    }
}
