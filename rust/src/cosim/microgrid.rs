//! The microgrid power-balance core: load vs solar vs battery vs grid,
//! one fixed-width step at a time.
//!
//! Balance policy per step (identical to python/compile/kernels/ref.py
//! `ref_microgrid` and verified against the AOT cosim kernel):
//!   1. solar serves the load;
//!   2. excess solar charges the battery, remainder exports;
//!   3. residual load discharges the battery, remainder imports;
//!   4. emissions = imported energy × carbon intensity.

use crate::battery::Battery;

/// One co-simulation step's resolved power flows.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepRecord {
    pub t_s: f64,
    pub load_w: f64,
    pub solar_w: f64,
    /// Solar power directly consumed by the load.
    pub solar_used_w: f64,
    /// Grid power: >0 import, <0 export.
    pub grid_w: f64,
    /// Battery power: >0 discharge, <0 charge.
    pub battery_w: f64,
    pub soc: f64,
    /// Grid carbon intensity this step, g/kWh.
    pub ci: f64,
    /// Emissions from imports this step, g.
    pub emissions_g: f64,
}

impl StepRecord {
    /// Power-balance residual (0 when consistent): load = solar_used +
    /// discharge + import.
    pub fn balance_residual(&self) -> f64 {
        let import = self.grid_w.max(0.0);
        let discharge = self.battery_w.max(0.0);
        self.load_w - (self.solar_used_w + discharge + import)
    }
}

/// Microgrid state: the battery plus cumulative counters.
#[derive(Debug, Clone)]
pub struct Microgrid {
    pub battery: Battery,
    pub total_load_wh: f64,
    pub total_solar_wh: f64,
    pub total_solar_used_wh: f64,
    pub total_import_wh: f64,
    pub total_export_wh: f64,
    pub total_emissions_g: f64,
}

impl Microgrid {
    pub fn new(battery: Battery) -> Self {
        Microgrid {
            battery,
            total_load_wh: 0.0,
            total_solar_wh: 0.0,
            total_solar_used_wh: 0.0,
            total_import_wh: 0.0,
            total_export_wh: 0.0,
            total_emissions_g: 0.0,
        }
    }

    /// Resolve one step.
    pub fn step(&mut self, t_s: f64, load_w: f64, solar_w: f64, ci: f64, dt_s: f64) -> StepRecord {
        let dt_h = dt_s / 3600.0;
        let solar_used = solar_w.min(load_w);
        let excess = solar_w - solar_used;
        let deficit = load_w - solar_used;

        let charged = self.battery.charge(excess, dt_s);
        let export = excess - charged;

        let discharged = self.battery.discharge(deficit, dt_s);
        let import = deficit - discharged;

        let emissions = import * dt_h / 1000.0 * ci;

        self.total_load_wh += load_w * dt_h;
        self.total_solar_wh += solar_w * dt_h;
        self.total_solar_used_wh += solar_used * dt_h;
        self.total_import_wh += import * dt_h;
        self.total_export_wh += export * dt_h;
        self.total_emissions_g += emissions;

        StepRecord {
            t_s,
            load_w,
            solar_w,
            solar_used_w: solar_used,
            grid_w: import - export,
            battery_w: discharged - charged,
            soc: self.battery.soc,
            ci,
            emissions_g: emissions,
        }
    }

    /// Renewable share of consumption: solar directly used (plus
    /// battery-stored solar, approximated by total discharge) over load.
    pub fn renewable_share(&self) -> f64 {
        if self.total_load_wh == 0.0 {
            return 0.0;
        }
        ((self.total_solar_used_wh + self.battery.discharged_wh) / self.total_load_wh)
            .min(1.0)
    }

    pub fn grid_dependency(&self) -> f64 {
        if self.total_load_wh == 0.0 {
            return 0.0;
        }
        self.total_import_wh / self.total_load_wh
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::simconfig::CosimConfig;
    use crate::util::proptest::{check, gens};
    use crate::util::rng::Rng;

    fn grid() -> Microgrid {
        Microgrid::new(Battery::from_config(&CosimConfig::default()))
    }

    #[test]
    fn no_solar_full_import() {
        let mut g = grid();
        // Battery at min first.
        g.battery.soc = g.battery.soc_min;
        let r = g.step(0.0, 300.0, 0.0, 400.0, 60.0);
        assert_eq!(r.grid_w, 300.0);
        assert_eq!(r.battery_w, 0.0);
        assert!((r.emissions_g - 300.0 / 60.0 / 1000.0 * 400.0).abs() < 1e-12);
        assert!(r.balance_residual().abs() < 1e-9);
    }

    #[test]
    fn surplus_charges_then_exports() {
        let mut g = grid();
        g.battery.soc = 0.5;
        // 500 W solar vs 100 W load: 400 W excess; battery takes up to
        // 100 W (rate limit), 300 W exports.
        let r = g.step(0.0, 100.0, 500.0, 100.0, 60.0);
        assert_eq!(r.solar_used_w, 100.0);
        assert_eq!(r.battery_w, -100.0);
        assert_eq!(r.grid_w, -300.0);
        assert_eq!(r.emissions_g, 0.0); // no import
        assert!(r.balance_residual().abs() < 1e-9);
    }

    #[test]
    fn deficit_discharges_then_imports() {
        let mut g = grid();
        g.battery.soc = 0.8;
        // 300 W load, no solar: battery gives 100 W (rate), 200 W import.
        let r = g.step(0.0, 300.0, 0.0, 250.0, 60.0);
        assert_eq!(r.battery_w, 100.0);
        assert_eq!(r.grid_w, 200.0);
        assert!(r.balance_residual().abs() < 1e-9);
    }

    #[test]
    fn counters_accumulate() {
        let mut g = grid();
        for i in 0..60 {
            g.step(i as f64 * 60.0, 200.0, 100.0, 300.0, 60.0);
        }
        // One hour: 200 Wh load, 100 Wh solar (all used).
        assert!((g.total_load_wh - 200.0).abs() < 1e-9);
        assert!((g.total_solar_used_wh - 100.0).abs() < 1e-9);
        assert!(g.total_import_wh > 0.0);
        assert!(g.renewable_share() > 0.49);
        assert!(g.grid_dependency() < 0.51);
    }

    #[test]
    fn property_balance_and_soc_bounds() {
        check(30, gens::u64_in(0, u64::MAX / 2), |&seed| {
            let mut rng = Rng::new(seed);
            let mut g = grid();
            for i in 0..500 {
                let load = rng.uniform(0.0, 800.0);
                let solar = rng.uniform(0.0, 700.0);
                let ci = rng.uniform(50.0, 600.0);
                let r = g.step(i as f64 * 60.0, load, solar, ci, 60.0);
                if r.balance_residual().abs() > 1e-6 {
                    return Err(format!("imbalance {r:?}"));
                }
                if r.soc < g.battery.soc_min - 1e-9 || r.soc > g.battery.soc_max + 1e-9 {
                    return Err(format!("soc out of window {r:?}"));
                }
                if r.emissions_g < 0.0 {
                    return Err("negative emissions".into());
                }
            }
            Ok(())
        });
    }
}
