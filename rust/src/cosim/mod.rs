//! Vessim-like energy-system co-simulation: actors (power consumers /
//! producers), a microgrid with battery storage, controllers
//! (Monitor, CarbonLogger, carbon-aware scheduling), and the stepped
//! environment that executes them at a fixed resolution (paper
//! default: 1 minute).

pub mod microgrid;
pub mod environment;
pub mod controllers;

pub use controllers::{CarbonAwareController, ControllerAction};
pub use environment::{default_signal_traces, default_signals, CosimResult, Environment};
pub use microgrid::{Microgrid, StepRecord};
