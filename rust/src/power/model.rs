//! The Eq. 1 MFU→power law with swappable parameters (for the γ /
//! mfu_sat sensitivity ablation) and two baseline estimators:
//!
//! * `NvmlProxy` — power from kernel-occupancy-style utilization,
//!   which stays near 100% whenever any kernel is resident: models the
//!   §2 claim that NVML-style utilization cannot distinguish
//!   memory-stalled decode from saturated compute.
//! * `StaticTdp` — LLMCarbon-style constant draw at a fixed fraction
//!   of TDP regardless of workload.

use crate::config::gpus::GpuSpec;

/// Eq. 1 parameters, detached from the GPU registry so ablations can
/// sweep them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerParams {
    pub p_idle: f64,
    pub p_max: f64,
    pub mfu_sat: f64,
    pub gamma: f64,
}

impl PowerParams {
    pub fn from_gpu(g: &GpuSpec) -> Self {
        PowerParams {
            p_idle: g.p_idle,
            p_max: g.p_max_inst,
            mfu_sat: g.mfu_sat,
            gamma: g.gamma,
        }
    }

    pub fn power_vec(&self) -> [f32; 4] {
        [
            self.p_idle as f32,
            self.p_max as f32,
            self.mfu_sat as f32,
            self.gamma as f32,
        ]
    }
}

/// A power estimator mapping per-stage telemetry to per-GPU watts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PowerModel {
    /// The paper's Eq. 1 sublinear MFU power law.
    MfuPowerLaw(PowerParams),
    /// NVML-style: any non-empty stage counts as `busy_util` utilization.
    NvmlProxy { p_idle: f64, p_max: f64, busy_util: f64 },
    /// Constant fraction of peak (LLMCarbon-style lifecycle estimate).
    StaticTdp { p_max: f64, fraction: f64 },
}

impl PowerModel {
    pub fn paper_default(g: &GpuSpec) -> Self {
        PowerModel::MfuPowerLaw(PowerParams::from_gpu(g))
    }

    /// Per-GPU power for a stage with the given MFU. `busy` is false
    /// for idle gaps (no resident kernel).
    pub fn power(&self, mfu: f64, busy: bool) -> f64 {
        match self {
            PowerModel::MfuPowerLaw(p) => {
                let x = (mfu / p.mfu_sat).clamp(0.0, 1.0);
                p.p_idle + (p.p_max - p.p_idle) * x.powf(p.gamma)
            }
            PowerModel::NvmlProxy {
                p_idle,
                p_max,
                busy_util,
            } => {
                if busy {
                    p_idle + (p_max - p_idle) * busy_util
                } else {
                    *p_idle
                }
            }
            PowerModel::StaticTdp { p_max, fraction } => p_max * fraction,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::gpus;

    #[test]
    fn paper_law_matches_gpu_registry() {
        let g = gpus::gpu("a100-80g").unwrap();
        let m = PowerModel::paper_default(g);
        for mfu in [0.0, 0.1, 0.3, 0.45, 0.8] {
            assert!((m.power(mfu, true) - g.power(mfu)).abs() < 1e-12);
        }
    }

    #[test]
    fn nvml_proxy_overestimates_decode() {
        // §2: during memory-bound decode (low MFU), an occupancy-based
        // estimator reports near-max power while the MFU law doesn't.
        let g = gpus::gpu("a100-80g").unwrap();
        let law = PowerModel::paper_default(g);
        let nvml = PowerModel::NvmlProxy {
            p_idle: 100.0,
            p_max: 400.0,
            busy_util: 0.95,
        };
        let decode_mfu = 0.05;
        assert!(nvml.power(decode_mfu, true) > law.power(decode_mfu, true) + 80.0);
        // Idle agrees.
        assert_eq!(nvml.power(0.0, false), 100.0);
    }

    #[test]
    fn static_tdp_ignores_workload() {
        let m = PowerModel::StaticTdp {
            p_max: 400.0,
            fraction: 0.8,
        };
        assert_eq!(m.power(0.0, false), 320.0);
        assert_eq!(m.power(0.45, true), 320.0);
    }

    #[test]
    fn gamma_sweep_changes_midrange_only() {
        let g = gpus::gpu("a100-80g").unwrap();
        let mut p = PowerParams::from_gpu(g);
        let base_mid = PowerModel::MfuPowerLaw(p).power(0.2, true);
        p.gamma = 1.0; // linear
        let lin_mid = PowerModel::MfuPowerLaw(p).power(0.2, true);
        assert!(base_mid > lin_mid, "sublinear must exceed linear mid-range");
        // Endpoints invariant to gamma.
        assert_eq!(PowerModel::MfuPowerLaw(p).power(0.0, true), 100.0);
        assert!((PowerModel::MfuPowerLaw(p).power(0.45, true) - 400.0).abs() < 1e-9);
    }
}
