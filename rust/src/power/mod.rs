//! GPU power modeling — the paper's Eq. 1 plus the baseline estimators
//! used for comparison (§2's motivation: utilization-based proxies
//! overestimate decode power; LLMCarbon-style static models miss
//! workload dynamics).

pub mod model;

pub use model::{PowerModel, PowerParams};
