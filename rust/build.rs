//! Embed the git describe string (when a git checkout and binary are
//! available) so `repro --version` and `GET /healthz` can report the
//! exact build alongside the crate version. Absence of git is not an
//! error — release tarballs and sandboxed builds simply omit the
//! suffix (`util::version` treats the env var as optional).

use std::process::Command;

fn main() {
    // Re-run when HEAD moves so the string tracks the checkout. The
    // repository root is one level above the cargo package.
    println!("cargo:rerun-if-changed=../.git/HEAD");
    println!("cargo:rerun-if-changed=../.git/refs");
    let describe = Command::new("git")
        .args(["describe", "--always", "--dirty", "--tags"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .unwrap_or_default();
    if !describe.is_empty() {
        println!("cargo:rustc-env=REPRO_GIT_DESCRIBE={describe}");
    }
}
